"""The shared multi-tenant Fabric: one device pool, many gangs (§2.1).

Faabric's core claim is that *many applications share one cluster* under
fine-grained (Granule-level) scheduling with preemption-safe elasticity
and locality-driven migration.  This module is that shared layer for the
live runtime:

* ``Fabric`` owns the host fabric — the concrete jax devices, the
  per-host free-device pool (including the ragged last host), and the
  ``PlacementEngine`` that every tenant's placement decision goes
  through.  Multiple gangs coexist on one fabric with disjoint device
  sets; chips released by one gang are immediately placeable for
  another.

* ``GangHandle`` encapsulates one gang's lifecycle::

      allocate -> build mesh/GranuleGroup -> step -> control point
               -> migrate / rescale / preempt -> resume -> release

  Placement changes re-address the ``GranuleGroup`` *in place*
  (``readdress``/``resize``) so rank-keyed control-plane queues and the
  migration epoch survive the move, as the paper requires (Fig 8).
  Workload state moves with ``core.migration``/``core.snapshot``:
  migrate/rescale reshard live state onto the new sub-mesh; preempt
  checkpoints state to a host-side ``Snapshot`` and frees the chips;
  resume restores bit-exactly (fingerprint-verified) on a fresh
  placement.

* ``LiveTraceRunner`` closes the simulate→execute gap: it subclasses the
  discrete-event ``Simulator`` — inheriting the queueing discipline,
  priority classes, Poisson arrivals, preemption and the placement
  engine — and overrides the event hooks to run *real* train/serve gangs
  on the fabric while virtual time drives scheduling.  Because live
  execution and ``Fabric.predict_trace`` share one event loop and one
  placement code path, the live per-job completion order is directly
  comparable with the simulated prediction for the same trace.

* **Fleet churn, live** (``core.fleet``): hosts lease in and out under
  running gangs.  A ``join`` pulls staged spare devices into the pool;
  a ``reclaim`` drains hosts — affected gangs move through the shared
  evacuation planner (the ``GangHandle.migrate`` machinery: live
  reshard + in-place re-address) — and a hard ``fail`` drops a gang's
  devices mid-run: the gang falls back to its *last checkpoint
  snapshot* (``GangHandle.checkpoint`` / the trace runner's periodic
  ``checkpoint_interval``) and later resumes bit-exactly
  (fingerprint-verified) through the same preemption-resume machinery.
  ``Fabric.fail_hosts`` / ``Fabric.reclaim_hosts`` expose the same
  semantics to direct (non-trace) drivers.

Workload protocol (implemented by ``runtime.gang_workloads``): a gang's
payload is any object with

    ``state``                 replicated pytree — the snapshot/migration
                              unit (None until started)
    ``steps_done`` / ``total_steps`` / ``done``
    ``bind(handle)``          (re)compile step fns for ``handle.mesh``;
                              called at start and after every placement
                              change
    ``init_state(handle)``    create ``state`` (first start only)
    ``run_step(handle)``      execute one real step, advance
                              ``steps_done``, return a metrics dict
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh

from repro.core import collectives as coll
from repro.core import control as ctl
from repro.core import diffsync
from repro.core import telemetry
from repro.core import elastic as elastic_mod
from repro.core import snapshot as snap_mod
from repro.core.granule import GranuleGroup
from repro.core.placement import (Allocation, CostModel, PlacementEngine,
                                  PlacementPolicy, PreemptPolicy,
                                  ShardedPlacementEngine, derive_capacities)
from repro.core.simulator import Job, Simulator, TraceResult

# Relative per-chip speed by device generation, used to auto-detect a
# mixed-generation pool (unknown kinds count as current-generation 1.0).
DEVICE_KIND_SPEEDS = {
    "TPU v5": 1.0, "TPU v4": 0.75, "TPU v3": 0.45, "TPU v2": 0.25,
}


def infer_host_speeds(devices: Sequence[Any], chips_per_host: int
                      ) -> Optional[List[float]]:
    """Per-host speed factors for a mixed device pool, or ``None`` for a
    uniform pool (the homogeneous fast path).  Hosts follow the same
    consecutive-run layout as ``derive_capacities``; a host's speed is
    the mean of its devices' generation factors."""
    kinds = [str(getattr(d, "device_kind", "")) for d in devices]
    if len(set(kinds)) <= 1:
        return None
    speeds, i = [], 0
    for cap in derive_capacities(len(devices), chips_per_host):
        factors = [DEVICE_KIND_SPEEDS.get(k, 1.0) for k in kinds[i:i + cap]]
        speeds.append(float(np.mean(factors)))
        i += cap
    return speeds


def make_gang_mesh(devices: Sequence[Any], pods: int = 1) -> Mesh:
    """Gang mesh: 1-D ``(data,)``, or two-level ``(pod, data)`` when the
    gang divides into ``pods`` equal pods."""
    devs = np.asarray(list(devices))
    if pods > 1 and len(devices) % pods == 0:
        return Mesh(devs.reshape(pods, -1), ("pod", "data"))
    return Mesh(devs, ("data",))


class GangWorkload:
    """Minimal base for the workload protocol (see module docstring)."""

    state: Any = None
    steps_done: int = 0
    total_steps: int = 0

    @property
    def done(self) -> bool:
        return self.steps_done >= self.total_steps

    def bind(self, handle: "GangHandle") -> None:
        raise NotImplementedError

    def init_state(self, handle: "GangHandle") -> None:
        raise NotImplementedError

    def run_step(self, handle: "GangHandle") -> Dict[str, Any]:
        raise NotImplementedError


def _gang_span(name: str):
    """Wall-clock lifecycle span around a GangHandle method — zero-cost
    (plain call-through) under the default no-op telemetry recorder."""
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(self, *args, **kwargs):
            tel = telemetry.get()
            if not tel.enabled:
                return fn(self, *args, **kwargs)
            t0 = time.perf_counter()
            try:
                return fn(self, *args, **kwargs)
            finally:
                pl = (self.alloc.placement
                      if self.alloc is not None else [])
                tel.count(f"gang.{name}")
                tel.span_at(f"gang.{name}", t0, time.perf_counter(),
                            track=f"gang:{self.job_id}", clock="wall",
                            job=self.job_id, kind=self.kind,
                            chips=len(self.devices),
                            hosts=len({h for h, _ in pl}))
        return wrapper
    return deco


class GangHandle:
    """One gang's lifecycle on a shared ``Fabric``.

    The handle owns the gang's *placement* artifacts — ``Allocation``,
    concrete devices, ``GranuleGroup``, mesh — and moves the caller's
    (opaque, replicated) state pytree through placement changes.  State
    is passed in and returned functionally so drivers keep ownership.
    """

    def __init__(self, fabric: "Fabric", job_id: str, priority: int = 0,
                 pods: int = 1,
                 policy: Union[str, PlacementPolicy, None] = None,
                 kind: Optional[str] = None):
        self.fabric = fabric
        self.job_id = job_id
        self.priority = priority
        self.pods = pods
        self.policy = policy
        self.kind = kind            # trace job kind -> per-kind beta
        self.alloc: Optional[Allocation] = None
        self.devices: List[Any] = []
        self.group: Optional[GranuleGroup] = None
        self.mesh: Optional[Mesh] = None
        self.snapshot: Optional[snap_mod.Snapshot] = None
        # the periodic checkpoint a hard host failure falls back to
        # (kept separate from ``snapshot``, which preempt/resume consume)
        self.last_checkpoint: Optional[snap_mod.Snapshot] = None
        # delta checkpointing (core.diffsync): after a full base
        # snapshot, each cadence tick ships only the chunk diff against
        # the previous checkpoint; a full rebase every
        # ``ckpt_rebase_every`` ticks bounds the recovery replay chain.
        # Matches CostModel.checkpoint_cost(index) charging: index 0
        # (the start baseline) and every rebase point are full.
        self.ckpt_rebase_every: int = 8
        self._ckpt_base: Optional[snap_mod.Snapshot] = None
        self._ckpt_deltas: List[Dict[str, Any]] = []
        self.ckpt_stats: List[Dict[str, Any]] = []
        self.status = "created"     # created|running|preempted|released
        self.control: Optional[ctl.ControlPointRunner] = None
        self.epoch_log: List[Dict[str, Any]] = []

    @property
    def n(self) -> int:
        return len(self.devices)

    # ---- attach / detach (device + group bookkeeping) ----------------------
    @_gang_span("attach")
    def attach(self, alloc: Allocation,
               devices: Optional[Sequence[Any]] = None) -> None:
        """Bind this gang to an engine allocation: claim concrete devices
        and build (or in-place re-address) the GranuleGroup and mesh."""
        self.alloc = alloc
        self.devices = list(devices if devices is not None
                            else self.fabric.claim(alloc.placement))
        placement = [(self.fabric.host_of(d), d) for d in self.devices]
        if self.group is None:
            self.group = GranuleGroup(self.job_id, len(self.devices),
                                      placement)
        elif self.group.size == len(self.devices):
            self.group.readdress(placement)     # queues + epoch survive
        else:
            self.group.resize(placement)
        self.mesh = make_gang_mesh(self.devices, self.pods)
        self.status = "running"
        self.fabric.tuner.on_placement_change(self.job_id, alloc.placement)

    def detach(self) -> None:
        """Return devices to the fabric pool (engine accounting is the
        caller's: release/preempt handle it in engine-managed mode, the
        trace runner's event loop in adopted mode)."""
        self.fabric.reclaim(self.devices)
        self.devices = []
        self.alloc = None

    # ---- collective schedule dispatch --------------------------------------
    def best_sync_mode(self, nbytes: Optional[int] = None) -> str:
        """The collective schedule the fabric's ``CollectiveTuner``
        dispatches for this gang's *current* placement and message size
        (re-derived on every attach / migrate / evacuate / rescale).
        A single-axis gang mesh (``pods == 1``) has no slow axis to run
        the pod-level compressed schedule over, so the choice is
        restricted accordingly."""
        placement = (self.alloc.placement if self.alloc is not None
                     else [(0, max(1, len(self.devices)))])
        allowed = None if self.pods > 1 else ("flat", "ring",
                                              "hierarchical")
        return self.fabric.tuner.mode_for(placement, nbytes,
                                          allowed=allowed)

    # ---- control point -----------------------------------------------------
    def control_point(self, step: int, step_time: float) -> List[ctl.Action]:
        """Evaluate this gang's step-boundary control point (checkpoint /
        migrate / rescale / recover triggers)."""
        if self.control is None:
            return []
        return self.control.on_step(step, step_time, len(self.devices))

    # ---- migrate / evacuate ------------------------------------------------
    def _move_to(self, state: Any, new_devices: List[Any],
                 log_kind: str) -> Any:
        """Live placement move: reshard state onto ``new_devices`` and
        re-address the group in place (queues + epoch survive)."""
        state, _ = elastic_mod.reshard_gang(state, new_devices)
        self.devices = new_devices
        self.group.readdress([(self.fabric.host_of(d), d)
                              for d in new_devices])
        self.mesh = make_gang_mesh(new_devices, self.pods)
        if self.alloc is not None:
            self.fabric.tuner.on_placement_change(self.job_id,
                                                  self.alloc.placement)
        self.epoch_log.append({"kind": log_kind,
                               "epoch": self.group.epoch})
        return state

    @_gang_span("migrate")
    def migrate(self, state: Any) -> Tuple[Any, bool]:
        """Barrier-point live migration (paper §3.3, Fig 8).

        The engine plans a consolidation onto fewer hosts; when none
        exists the gang rotates rank order within its own chips, which
        still exercises the full machinery (barrier, live resharding,
        in-place group re-addressing).  Returns (state, devices_changed).
        """
        assert self.status == "running"
        engine = self.fabric.engine
        plans = engine.migration_plan([self.alloc],
                                      kinds={self.job_id: self.kind})
        if plans:
            _, new_pl = plans[0]
            self.alloc = engine.apply_migration(self.alloc, new_pl)
            self.fabric.reclaim(self.devices)
            new_devices = self.fabric.claim(new_pl)
        else:
            new_devices = self.devices[1:] + self.devices[:1]
        changed = new_devices != self.devices
        state = self._move_to(state, new_devices, "migrate")
        return state, changed

    @_gang_span("evacuate")
    def evacuate(self, state: Any,
                 new_placement: Sequence[Tuple[int, int]]) -> Any:
        """Apply a drain-evacuation plan (``evacuation_plan``): engine
        move + live reshard through the migrate machinery.  The vacated
        draining-host chips retire on release; their devices never
        return to the pool."""
        assert self.status == "running"
        self.alloc = self.fabric.engine.apply_migration(self.alloc,
                                                        new_placement)
        self.fabric.reclaim(self.devices)     # draining devices dropped
        new_devices = self.fabric.claim(new_placement)
        return self._move_to(state, new_devices, "evacuate")

    # ---- rescale -----------------------------------------------------------
    @_gang_span("rescale")
    def rescale(self, state: Any, new_world: int) -> Any:
        """Grow/shrink to ``new_world`` chips: release this gang's chips
        to the shared pool and let the engine carve the new sub-mesh
        under the configured policy (paper §2.1)."""
        assert self.status == "running"
        engine = self.fabric.engine
        new_world = min(new_world, engine.total_chips)
        old_placement = self.alloc.placement
        old_devices = self.devices
        engine.release(self.alloc)
        self.fabric.reclaim(old_devices)
        alloc = engine.allocate(self.job_id, new_world, policy=self.policy,
                                kind=self.kind)
        if alloc is None:            # other tenants hold the delta: undo
            self.alloc = engine.bind(self.job_id, old_placement)
            self.devices = self.fabric.claim_exact(old_devices)
            raise RuntimeError(
                f"rescale to {new_world} not placeable on shared fabric")
        self.alloc = alloc
        new_devices = self.fabric.claim(alloc.placement)
        state, _ = elastic_mod.reshard_gang(state, new_devices)
        self.devices = new_devices
        self.group.resize([(self.fabric.host_of(d), d)
                           for d in new_devices])
        self.mesh = make_gang_mesh(new_devices, self.pods)
        self.fabric.tuner.on_placement_change(self.job_id, alloc.placement)
        self.epoch_log.append({"kind": "rescale", "to": new_world,
                               "epoch": self.group.epoch})
        return state

    # ---- checkpoint / fail (fleet churn) ------------------------------------
    def _chain_reset(self) -> None:
        self._ckpt_base = None
        self._ckpt_deltas = []

    @staticmethod
    def _same_layout(a, b) -> bool:
        la, sa = jax.tree_util.tree_flatten(a)
        lb, sb = jax.tree_util.tree_flatten(b)
        return (sa == sb and len(la) == len(lb)
                and all(np.asarray(x).shape == np.asarray(y).shape
                        and np.asarray(x).dtype == np.asarray(y).dtype
                        for x, y in zip(la, lb)))

    @_gang_span("checkpoint")
    def checkpoint(self, state: Any, step: int) -> snap_mod.Snapshot:
        """Periodic checkpoint: snapshot the gang's state to host memory
        without releasing anything — the rollback point a hard host
        failure falls back to (``fail``).

        Incremental: the first checkpoint (and every
        ``ckpt_rebase_every``-th, or any after the state layout changes
        — e.g. a rescale) is a full base; the ticks between ship only
        the ``core.diffsync`` chunk diff against the previous
        checkpoint, so the recurring cost scales with the bytes the gang
        actually dirtied.  ``fail`` replays base+deltas and proves the
        chain bit-exact against the recorded fingerprint."""
        tel = telemetry.get()
        t_ckpt = time.perf_counter() if tel.enabled else 0.0
        snap = snap_mod.take(self.job_id, step, state)
        prev = self.last_checkpoint
        rebase = (self._ckpt_base is None
                  or len(self._ckpt_deltas) >= self.ckpt_rebase_every - 1
                  or prev is None
                  or not self._same_layout(prev.state, snap.state))
        if rebase:
            self._ckpt_base = snap
            self._ckpt_deltas = []
            ckpt_kind, shipped = "full", snap.nbytes
        else:
            diffs = diffsync.diff_tree(prev.state, snap.state,
                                       op="overwrite")
            self._ckpt_deltas.append(
                {"step": step, "diffs": diffs,
                 "fingerprint": snap.fingerprint})
            ckpt_kind, shipped = "delta", diffsync.diff_nbytes(diffs)
        self.last_checkpoint = snap
        self.ckpt_stats.append({"step": step, "kind": ckpt_kind,
                                "bytes": shipped,
                                "full_bytes": snap.nbytes})
        if tel.enabled:
            tel.count(f"ckpt.{ckpt_kind}")
            tel.count("ckpt.bytes_shipped", shipped)
            tel.count("ckpt.bytes_full", snap.nbytes)
            tel.gauge("ckpt.chain_len", len(self._ckpt_deltas))
            tel.span_at("ckpt.save", t_ckpt, time.perf_counter(),
                        track=f"gang:{self.job_id}", clock="wall",
                        step=step, kind=ckpt_kind, bytes=shipped,
                        full_bytes=snap.nbytes)
        self.epoch_log.append(
            {"kind": "checkpoint", "step": step,
             "fingerprint": snap.fingerprint,
             "ckpt_kind": ckpt_kind, "bytes": shipped})
        return snap

    @_gang_span("fail")
    def fail(self, dead_hosts: Sequence[int]) -> snap_mod.Snapshot:
        """A host under this gang hard-failed: the live state is gone.
        Surviving devices return to the pool (dead/draining ones are
        dropped by ``Fabric.reclaim``), and the gang becomes
        ``preempted`` with its *last checkpoint* as the resume snapshot
        — ``resume`` then restores it bit-exactly on a fresh placement.
        Engine accounting is already settled by
        ``PlacementEngine.fail_hosts``; the caller requeues the job."""
        assert self.status == "running"
        assert self.last_checkpoint is not None, \
            f"{self.job_id}: host failed before any checkpoint was taken"
        dead = {int(h) for h in dead_hosts}
        survivors = [d for d in self.devices
                     if self.fabric.host_of(d) not in dead]
        self.fabric.reclaim(survivors)
        self.devices = []
        self.alloc = None
        # recovery replays the (base, delta*) chain — every hard
        # failure proves the delta checkpoints reconstruct the rollback
        # point bit-exactly (fingerprint check against the value
        # recorded when the checkpoint was taken)
        tel = telemetry.get()
        if self._ckpt_base is not None and self._ckpt_deltas:
            t_replay = time.perf_counter()
            chain_len = len(self._ckpt_deltas)
            snap = self._ckpt_base
            for link in self._ckpt_deltas:
                snap = snap_mod.apply_delta(snap, link["diffs"],
                                            link["step"])
                if snap.fingerprint != link["fingerprint"]:
                    raise RuntimeError(
                        f"{self.job_id}: delta-chain replay diverged "
                        f"at step {link['step']}")
            self.snapshot = snap
            if tel.enabled:
                tel.count("ckpt.chain_replays")
                tel.observe("ckpt.replay_verify_s",
                            time.perf_counter() - t_replay)
                tel.gauge("ckpt.replayed_chain_len", chain_len)
        else:
            self.snapshot = self.last_checkpoint
        # the chain is consumed: the post-recovery baseline checkpoint
        # starts a fresh base (CostModel charges index 0 as full)
        self._chain_reset()
        self.status = "preempted"
        self.epoch_log.append(
            {"kind": "fail", "step": self.snapshot.step,
             "fingerprint": self.snapshot.fingerprint})
        return self.snapshot

    # ---- preempt / resume ---------------------------------------------------
    @_gang_span("preempt")
    def preempt(self, state: Any, step: int,
                release_engine: bool = True) -> snap_mod.Snapshot:
        """Checkpoint + release: snapshot the gang's state to host
        memory, free its chips for the preemptor, keep the group (queues
        and epoch survive suspension).  The caller requeues the job."""
        assert self.status == "running"
        self.snapshot = snap_mod.take(self.job_id, step, state)
        if release_engine:
            self.fabric.engine.release(self.alloc)
        self.detach()
        self.status = "preempted"
        self.epoch_log.append({"kind": "preempt", "step": step,
                               "fingerprint": self.snapshot.fingerprint})
        return self.snapshot

    @_gang_span("resume")
    def resume(self, alloc: Optional[Allocation] = None,
               verify: bool = True) -> Tuple[Any, int]:
        """Re-place and restore the preempted gang bit-exactly.

        ``alloc``: adopt an allocation the caller already made (trace
        runner); None allocates through the engine.  Returns
        (state, step); raises if no placement or the restore is not
        bit-exact (fingerprint mismatch).
        """
        assert self.status == "preempted" and self.snapshot is not None
        if alloc is None:
            alloc = self.fabric.engine.allocate(
                self.job_id, self.snapshot_world(), policy=self.policy,
                kind=self.kind)
            if alloc is None:
                raise RuntimeError("resume: gang not placeable")
        self.attach(alloc)
        shardings = elastic_mod.replicated_shardings(self.snapshot.state,
                                                     self.mesh)
        state = snap_mod.restore(self.snapshot, shardings)
        if verify:
            check = snap_mod.take(self.job_id, self.snapshot.step, state)
            if check.fingerprint != self.snapshot.fingerprint:
                raise RuntimeError("resume: restored state is not "
                                   "bit-exact with the snapshot")
        step = self.snapshot.step
        self.epoch_log.append({"kind": "resume", "step": step,
                               "fingerprint": self.snapshot.fingerprint})
        self.snapshot = None
        # every (re)start segment opens with a fresh base checkpoint —
        # mirrors the simulator's per-RunningJob ckpt_count reset
        self._chain_reset()
        return state, step

    def snapshot_world(self) -> int:
        """World size to restore a preempted gang at (its group size)."""
        return self.group.size if self.group is not None else 0

    # ---- release -----------------------------------------------------------
    @_gang_span("release")
    def release(self) -> None:
        """Return the gang's chips to the shared pool."""
        if self.status == "running":
            self.fabric.engine.release(self.alloc)
            self.detach()
        self.status = "released"
        self.fabric.tuner.forget(self.job_id)
        self.fabric.gangs.pop(self.job_id, None)


class Fabric:
    """The shared device pool + placement engine all gangs run on.

    ``devices``: the concrete jax devices (default: all local devices);
    hosts are consecutive runs of ``chips_per_host`` devices, and the
    ragged last host is carried as a reduced per-host capacity in the
    engine (no phantom pad job) — both derived by the shared
    ``placement.derive_capacities`` via ``PlacementEngine.for_chips``.
    A mixed-generation device pool (differing ``device_kind``) is
    auto-detected into per-host ``speeds``; pass ``speeds`` explicitly
    to model a mixed fleet on uniform local devices (e.g.
    ``simulator.hetero_speeds``).
    ``shard_hosts`` builds the fabric over a decentralised
    ``ShardedPlacementEngine`` (host groups of that size) instead of the
    centralised engine — every gang decision then consults the shard
    summary index first; with one shard covering the fleet the two are
    decision-for-decision identical.
    """

    def __init__(self, devices: Optional[Sequence[Any]] = None,
                 chips_per_host: int = 4,
                 policy: Union[str, PlacementPolicy] = "binpack",
                 preempt: Optional[PreemptPolicy] = None,
                 speeds: Optional[Sequence[float]] = None,
                 cost_model: Optional[CostModel] = None,
                 shard_hosts: Union[int, str, None] = None,
                 steal_budget: int = 0,
                 spares: Optional[Sequence[Any]] = None,
                 tuner: Optional[coll.CollectiveTuner] = None):
        self.devices = list(devices if devices is not None
                            else jax.devices())
        assert self.devices, "empty fabric"
        self.chips_per_host = chips_per_host
        # topology-tuned collective dispatch (DESIGN.md §11): gangs
        # re-derive their entries on every placement change and ask it
        # for the sync schedule via GangHandle.best_sync_mode
        self.tuner = tuner or coll.CollectiveTuner(
            link=(cost_model.link if cost_model is not None else None))
        self._dev_index = {d: i for i, d in enumerate(self.devices)}
        if speeds is None:
            speeds = infer_host_speeds(self.devices, chips_per_host)
        if shard_hosts is None:
            self.engine = PlacementEngine.for_chips(
                len(self.devices), chips_per_host, policy=policy,
                speeds=speeds, cost_model=cost_model)
        else:
            self.engine = ShardedPlacementEngine.for_chips(
                len(self.devices), chips_per_host, policy=policy,
                speeds=speeds, cost_model=cost_model,
                hosts_per_shard=shard_hosts, steal_budget=steal_budget)
        self.preempt = preempt or PreemptPolicy()
        self.gangs: Dict[str, GangHandle] = {}
        # device -> host map (explicit: joined hosts and ragged hosts
        # break the old index//chips_per_host arithmetic) and per-host
        # free pools, both laid out by the engine's capacity runs
        self._dev_host: Dict[Any, int] = {}
        self._free: List[List[Any]] = []
        i = 0
        for h, cap in enumerate(self.engine.capacities):
            group = self.devices[i:i + int(cap)]
            i += int(cap)
            for d in group:
                self._dev_host[d] = h
            self._free.append(group)
        # fleet churn: staged spare devices (future joins draw from
        # them) and hosts whose devices must never re-enter the pool
        self.spares: List[Any] = list(spares or [])
        self._draining_hosts: set = set()
        self._retired_hosts: set = set()

    # ---- device pool -------------------------------------------------------
    def host_of(self, device: Any) -> int:
        return self._dev_host[device]

    def claim(self, placement: Sequence[Tuple[int, int]]) -> List[Any]:
        """Take the lowest-indexed free devices matching an engine
        placement (deterministic, so simulation and execution agree)."""
        out: List[Any] = []
        for h, c in placement:
            pool = self._free[h]
            assert len(pool) >= c, \
                f"host {h}: {c} chips claimed, {len(pool)} free"
            out.extend(pool[:c])
            self._free[h] = pool[c:]
        return out

    def claim_exact(self, devices: Sequence[Any]) -> List[Any]:
        """Take specific devices out of the free pool (bind/undo paths)."""
        for d in devices:
            self._free[self.host_of(d)].remove(d)
        return list(devices)

    def reclaim(self, devices: Sequence[Any]) -> None:
        doomed = self._draining_hosts | self._retired_hosts
        for d in devices:
            h = self.host_of(d)
            if h in doomed:
                continue              # the provider has these back
            self._free[h].append(d)
        for pool in self._free:
            pool.sort(key=self._dev_index.__getitem__)

    def idle_chips(self) -> int:
        return self.engine.idle_chips()

    # ---- fleet churn (pool side; engine accounting via core.fleet) ---------
    def take_spares(self, n: int) -> List[Any]:
        """Draw ``n`` staged spare devices for a join event."""
        assert len(self.spares) >= n, \
            f"join needs {n} spare devices, {len(self.spares)} staged"
        taken, self.spares = self.spares[:n], self.spares[n:]
        return taken

    def _pool_add_hosts(self, devices: Sequence[Any],
                        capacities: Sequence[int]) -> None:
        """Append joined devices as new host pools (engine indices were
        already assigned by ``PlacementEngine.add_hosts``)."""
        assert sum(capacities) == len(devices)
        base = len(self.devices)
        for j, d in enumerate(devices):
            self._dev_index[d] = base + j
        self.devices.extend(devices)
        i = 0
        for cap in capacities:
            h = len(self._free)
            group = list(devices[i:i + int(cap)])
            i += int(cap)
            for d in group:
                self._dev_host[d] = h
            self._free.append(group)
        assert len(self._free) == self.engine.hosts, \
            "pool and engine host maps diverged"

    def join_hosts(self, devices: Sequence[Any]) -> List[int]:
        """Lease new hosts into a live fabric (direct, non-trace API):
        engine capacity + device pool in one move.  Devices group into
        ``chips_per_host`` runs (ragged last host allowed).  Joiners'
        generation factors are inferred like the constructor's
        ``infer_host_speeds``: an older-generation host joining a
        uniform fleet re-opens the heterogeneous cost-model path at its
        speed relative to the incumbent generation."""
        caps = derive_capacities(len(devices), self.chips_per_host)
        kinds = [str(getattr(d, "device_kind", "")) for d in devices]
        new_speeds, i = [], 0
        for cap in caps:
            new_speeds.append(float(np.mean(
                [DEVICE_KIND_SPEEDS.get(k, 1.0)
                 for k in kinds[i:i + cap]])))
            i += cap
        if self.engine.speeds is not None:
            # engine already carries absolute generation factors
            speeds: Optional[List[float]] = new_speeds
        else:
            # uniform speedless fleet runs at relative 1.0; scale the
            # joiners against the incumbent generation and only
            # materialise speeds when they actually differ
            base_kinds = {str(getattr(d, "device_kind", ""))
                          for d in self.devices}
            base = (DEVICE_KIND_SPEEDS.get(next(iter(base_kinds)), 1.0)
                    if len(base_kinds) == 1 else 1.0)
            rel = [s / base for s in new_speeds]
            speeds = (None if all(abs(r - 1.0) < 1e-9 for r in rel)
                      else rel)
        new_idx = self.engine.add_hosts(caps, speeds=speeds)
        self._pool_add_hosts(list(devices), caps)
        return new_idx

    def mark_draining(self, hosts: Sequence[int]) -> None:
        """Pool side of a lease reclaim: free devices on the hosts go
        back to the provider now; gang devices follow as they leave
        (``reclaim`` drops them)."""
        for h in hosts:
            h = int(h)
            self._free[h] = []
            self._draining_hosts.add(h)

    def fail_hosts_pool(self, hosts: Sequence[int]) -> None:
        """Pool side of a host failure/retirement: the hosts' devices
        are gone for good."""
        for h in hosts:
            h = int(h)
            self._free[h] = []
            self._retired_hosts.add(h)
            self._draining_hosts.discard(h)

    def fail_hosts(self, hosts: Sequence[int]) -> List[str]:
        """Hard host failure against live gangs (direct, non-trace API):
        engine accounting drops the dead chips, each affected gang falls
        back to its last checkpoint snapshot (status ``preempted``).
        Returns the failed job_ids; the caller resumes each via
        ``GangHandle.resume`` (bit-exact, fingerprint-verified)."""
        failed = self.engine.fail_hosts(hosts)
        self.fail_hosts_pool(hosts)
        dead = {int(h) for h in hosts}
        for jid in failed:
            handle = self.gangs.get(jid)
            if handle is not None and handle.status == "running":
                handle.fail(dead)
        return failed

    def reclaim_hosts(self, hosts: Sequence[int]
                      ) -> Tuple[List[Tuple[str, Any]], List[str]]:
        """Begin a live lease reclaim (direct, non-trace API): the hosts
        drain, and the evacuation planner proposes moves for affected
        gangs.  Returns ``(plans, stranded)``; the caller — who owns
        each gang's state pytree — applies every plan with
        ``GangHandle.evacuate(state, placement)`` and, when the drain
        deadline passes, retires the hosts with ``fail_hosts``."""
        self.engine.drain_hosts(hosts)
        self.mark_draining(hosts)
        kinds = {jid: g.kind for jid, g in self.gangs.items()
                 if g.kind is not None}
        return self.engine.evacuation_plan(hosts, kinds=kinds)

    # ---- gang lifecycle ----------------------------------------------------
    def allocate(self, job_id: str, n: int, priority: int = 0,
                 pods: int = 1,
                 policy: Union[str, PlacementPolicy, None] = None,
                 kind: Optional[str] = None) -> Optional[GangHandle]:
        """Policy-driven gang allocation; None when it does not fit.
        ``kind`` (trace job kind) keys the CostModel's per-kind beta for
        this and every later placement decision of the gang."""
        alloc = self.engine.allocate(job_id, n, policy=policy, kind=kind)
        if alloc is None:
            return None
        handle = GangHandle(self, job_id, priority=priority, pods=pods,
                            policy=policy, kind=kind)
        handle.attach(alloc)
        self.gangs[job_id] = handle
        return handle

    def bind(self, job_id: str, devices: Sequence[Any], priority: int = 0,
             pods: int = 1,
             policy: Union[str, PlacementPolicy, None] = None,
             kind: Optional[str] = None) -> GangHandle:
        """Adopt an externally-chosen device list (a launch-time gang),
        preserving its rank order."""
        counts: Dict[int, int] = {}
        for d in devices:
            counts[self.host_of(d)] = counts.get(self.host_of(d), 0) + 1
        alloc = self.engine.bind(job_id, sorted(counts.items()))
        handle = GangHandle(self, job_id, priority=priority, pods=pods,
                            policy=policy, kind=kind)
        handle.attach(alloc, devices=self.claim_exact(devices))
        self.gangs[job_id] = handle
        return handle

    def adopt(self, alloc: Allocation, priority: int = 0, pods: int = 1,
              handle: Optional[GangHandle] = None,
              kind: Optional[str] = None) -> GangHandle:
        """Build/re-attach a handle for an allocation the engine already
        holds (the trace runner's event loop owns engine accounting)."""
        if handle is None:
            handle = GangHandle(self, alloc.job_id, priority=priority,
                                pods=pods, kind=kind)
        handle.attach(alloc)
        self.gangs[alloc.job_id] = handle
        return handle

    def priorities(self) -> Dict[str, int]:
        return {jid: h.priority for jid, h in self.gangs.items()}

    def preemption_plan(self, n: int, priority: int,
                        kind: Optional[str] = None) -> Optional[List[str]]:
        """Victims (lower-priority gangs) to evict so an ``n``-chip gang
        at ``priority`` fits — the live counterpart of the simulator's
        preemption step; the caller checkpoints + requeues them.
        ``kind`` feeds the arrival's per-kind beta into the fit probe."""
        return self.engine.preemption_plan(n, priority, self.priorities(),
                                           preempt=self.preempt, kind=kind)

    def grow_with_drain(self, handle: GangHandle, state: Any,
                        new_world: int,
                        donors: Sequence[Tuple[GangHandle, Any, int]] = ()
                        ) -> Tuple[Any, Dict[str, Any]]:
        """Grow a latency-sensitive gang (a serve gang under SLO
        pressure), *draining* elastic donors instead of killing anyone.

        Tries the plain ``rescale`` first; when the shared pool can't
        fit it, the largest donor gang halves (down to its floor) via
        its own ``rescale`` — a graceful shrink at the donor's control
        point that keeps every step of progress, unlike a preemption
        rollback — and the grow retries.  ``donors`` is
        ``[(handle, state, min_world), ...]`` for tenants whose state
        the caller owns (the autoscaler's training neighbours).

        Returns ``(state, {donor_job_id: new_donor_state})`` — donor
        states that were resharded.  Raises RuntimeError when the grow
        still doesn't fit after every donor is at its floor."""
        donor_states: Dict[str, Any] = {}
        pool = [[d, s, int(m)] for d, s, m in donors]
        while True:
            try:
                state = handle.rescale(state, new_world)
                return state, donor_states
            except RuntimeError:
                givers = [e for e in pool
                          if e[0].n // 2 >= e[2] and e[0].n > 1]
                if not givers:
                    raise
                entry = max(givers, key=lambda e: e[0].n)
                d_handle, d_state, d_min = entry
                entry[1] = d_handle.rescale(d_state,
                                            max(d_min, d_handle.n // 2))
                donor_states[d_handle.job_id] = entry[1]

    # ---- trace execution ---------------------------------------------------
    def run_trace(self, jobs: Sequence[Job],
                  workload_factory: Callable[[Job], GangWorkload],
                  policy: Union[str, PlacementPolicy, None] = None,
                  preempt: Union[bool, PreemptPolicy] = True,
                  migrate: bool = False, backfill: bool = False,
                  fleet_events: Optional[Sequence[Any]] = None,
                  checkpoint_interval: Optional[float] = None,
                  shrink_recovery: bool = False,
                  adapt_cadence: bool = False
                  ) -> "TraceExecution":
        """Execute an arrival-time trace — Poisson arrivals, priority
        classes, preemption — against real concurrent gangs on this
        fabric.  Scheduling runs on the simulator's virtual clock; gang
        steps are real jax computations.  ``fleet_events`` interleaves
        fleet churn (``core.fleet``): joins draw staged ``spares``,
        reclaims drain and evacuate live gangs, hard failures roll gangs
        back to their last real snapshot; ``checkpoint_interval`` sets
        the periodic live-checkpoint cadence.  ``shrink_recovery`` turns
        on shrink-before-rollback (stranded gangs reshard onto
        surviving capacity instead of rolling back; DESIGN.md §13) and
        ``adapt_cadence`` re-derives the Young/Daly interval from
        measured delta-checkpoint bytes after each rebase window (live
        only — it breaks Action-log parity with ``predict_trace``).
        See ``LiveTraceRunner``."""
        assert not self.gangs, "run_trace requires an idle fabric"
        runner = LiveTraceRunner(self, workload_factory,
                                 policy=policy or self.engine.default_policy,
                                 preempt=preempt, migrate=migrate,
                                 backfill=backfill,
                                 checkpoint_interval=checkpoint_interval,
                                 shrink_recovery=shrink_recovery,
                                 adapt_cadence=adapt_cadence)
        t0 = time.time()
        try:
            result = runner.run(list(jobs), fleet_events=fleet_events)
        finally:
            # hand the steal-budget lifecycle back to direct callers
            # (the runner's event loop owned it during the trace)
            self.engine.external_budget_reset = False
        tel = telemetry.get()
        if tel.enabled:
            # close item 2's loop: measured per-(host-kind, job-kind)
            # step times land in the cost model's calibration store
            tel.feed_cost_model(self.engine.cost_model)
        return TraceExecution(result=result, live=dict(runner.records),
                              wall_s=time.time() - t0)

    def predict_trace(self, jobs: Sequence[Job],
                      policy: Union[str, PlacementPolicy, None] = None,
                      preempt: Union[bool, PreemptPolicy] = True,
                      migrate: bool = False, backfill: bool = False,
                      fleet_events: Optional[Sequence[Any]] = None,
                      checkpoint_interval: Optional[float] = None,
                      shrink_recovery: bool = False
                      ) -> TraceResult:
        """Pure-simulation prediction for the same trace on a fabric of
        this shape (same hosts, capacities, per-host speeds, cost model
        — risk term and all, via ``clone_empty`` copying the lease
        metadata — policy, and centralised-vs-sharded engine
        architecture) — what ``run_trace`` should reproduce,
        placement-for-placement, churn schedule, shrink recoveries and
        all."""
        pol = policy or self.engine.default_policy
        engine = self.engine.clone_empty()
        sim = Simulator(engine.hosts, self.chips_per_host, "granular",
                        migrate=migrate, policy=pol, backfill=backfill,
                        preempt=preempt, engine=engine,
                        checkpoint_interval=checkpoint_interval,
                        shrink_recovery=shrink_recovery)
        return sim.run(list(jobs), fleet_events=fleet_events)


@dataclasses.dataclass
class TraceExecution:
    """Result of a live ``Fabric.run_trace``: the (virtual-time) trace
    result plus the per-job live execution log."""
    result: TraceResult
    live: Dict[str, Dict[str, Any]]
    wall_s: float = 0.0

    def job_makespans(self, jobs: Sequence[Job]) -> Dict[str, float]:
        return self.result.makespans(jobs)


class LiveTraceRunner(Simulator):
    """Trace-driven live execution (the simulate→execute bridge).

    Inherits the discrete-event loop — queueing discipline, priorities,
    Poisson arrivals, placement, preemption — and overrides the event
    hooks to drive *real* gangs on a shared ``Fabric``: virtual time
    decides *when/where*, the hooks execute *actual* train/serve steps on
    the allocated devices.  Because the loop and the placement engine are
    shared with the pure simulator, the live completion order matches
    ``Fabric.predict_trace`` for the same trace and policy.

    Interleaving: every event advances each running gang by one real
    step, so concurrent gangs genuinely alternate on the fabric; a
    finishing gang runs its remaining steps at its FINISH event; a
    preempted gang is checkpointed (snapshot) mid-run and later resumes
    bit-exactly on whatever placement the engine grants.
    """

    def __init__(self, fabric: Fabric,
                 workload_factory: Callable[[Job], GangWorkload],
                 policy: Union[str, PlacementPolicy] = "binpack",
                 preempt: Union[bool, PreemptPolicy] = True,
                 migrate: bool = False, backfill: bool = False,
                 checkpoint_interval: Optional[float] = None,
                 shrink_recovery: bool = False,
                 adapt_cadence: bool = False):
        super().__init__(fabric.engine.hosts, fabric.chips_per_host,
                         "granular", migrate=migrate, policy=policy,
                         backfill=backfill, preempt=preempt,
                         engine=fabric.engine,
                         checkpoint_interval=checkpoint_interval,
                         shrink_recovery=shrink_recovery)
        self.fabric = fabric
        self.factory = workload_factory
        self.workloads: Dict[str, GangWorkload] = {}
        self.handles: Dict[str, GangHandle] = {}
        self.records: Dict[str, Dict[str, Any]] = {}
        # set per run(): with churn possible, every gang start takes a
        # baseline snapshot so a hard failure always has a rollback point
        self._churn = checkpoint_interval is not None
        # adaptive Young/Daly cadence (opt-in; breaks Action-log parity
        # with predict_trace, which never sees the measured bytes):
        # after each rebase window the interval is re-derived from the
        # observed delta fraction — tau* scales as sqrt(delta), so
        # tau = tau0 * sqrt(eff_observed / eff_configured)
        self.adapt_cadence = adapt_cadence
        self._tau0 = checkpoint_interval

    def run(self, jobs, fleet_events=None):
        self._churn = bool(fleet_events) \
            or self.checkpoint_interval is not None
        return super().run(jobs, fleet_events=fleet_events)

    def _record(self, job_id: str) -> Dict[str, Any]:
        return self.records.setdefault(
            job_id, {"steps": 0, "preemptions": 0, "resumes_verified": 0,
                     "metrics": {}, "epochs": []})

    def _step_gang(self, job_id: str) -> None:
        wl = self.workloads[job_id]
        if wl.done:
            return
        handle = self.handles[job_id]
        tel = telemetry.get()
        if tel.enabled:
            t0 = time.perf_counter()
            metrics = wl.run_step(handle)
            dt = time.perf_counter() - t0
            hk = str(getattr(handle.devices[0], "device_kind", "cpu")
                     if handle.devices else "cpu")
            tel.step_time(hk, handle.kind or "train", dt)
            tel.count("gang.steps")
        else:
            metrics = wl.run_step(handle)
        rec = self._record(job_id)
        rec["steps"] = wl.steps_done
        rec["metrics"] = metrics

    # ---- hooks -------------------------------------------------------------
    def _on_start(self, rj, resumed: bool) -> None:
        job = rj.job
        wl = self.workloads.get(job.job_id)
        if wl is None:
            wl = self.workloads[job.job_id] = self.factory(job)
        handle = self.handles.get(job.job_id)
        if resumed:
            assert handle is not None and handle.status == "preempted"
            state, step = handle.resume(alloc=rj.alloc)  # bit-exact restore
            self.fabric.gangs[job.job_id] = handle
            wl.state = state
            # a recovery resume rolls the data cursor back to the
            # checkpointed step (a preemption resume restored the
            # suspension step: a no-op there)
            wl.steps_done = step
            wl.bind(handle)
            self._record(job.job_id)["resumes_verified"] += 1
        else:
            handle = self.fabric.adopt(rj.alloc, priority=job.priority,
                                       handle=handle, kind=job.kind)
            self.handles[job.job_id] = handle
            wl.bind(handle)
            if wl.state is None:
                wl.init_state(handle)
        self._record(job.job_id)["workload"] = type(wl).__name__
        if self._churn:
            # baseline rollback point: matches the simulator's
            # ckpt_progress = progress-at-start bookkeeping (index 0 of
            # the delta chain — always a full base)
            handle.ckpt_rebase_every = self.model.ckpt_rebase_every
            handle.checkpoint(wl.state, wl.steps_done)
        self._step_gang(job.job_id)    # gangs make real progress at start

    def _on_advance(self, now: float) -> None:
        # one real step per running gang per event: concurrent gangs
        # interleave on the fabric exactly as wall-clock sharing would
        for job_id, handle in self.handles.items():
            if handle.status == "running":
                self._step_gang(job_id)

    def _on_preempt(self, rj) -> None:
        job_id = rj.job.job_id
        handle = self.handles[job_id]
        wl = self.workloads[job_id]
        # engine accounting already released by the event loop
        handle.preempt(wl.state, wl.steps_done, release_engine=False)
        self.fabric.gangs.pop(job_id, None)
        wl.state = None               # lives in the snapshot until resume
        rec = self._record(job_id)
        rec["preemptions"] += 1
        rec["epochs"].append(handle.group.epoch)

    def _on_migrate(self, rj) -> None:
        job_id = rj.job.job_id
        handle = self.handles[job_id]
        wl = self.workloads[job_id]
        # the loop already applied the engine migration; move the gang:
        # reshard live state onto the new devices, then re-attach (the
        # in-place readdress keeps queues + epoch)
        self.fabric.reclaim(handle.devices)
        new_devices = self.fabric.claim(rj.alloc.placement)
        wl.state, _ = elastic_mod.reshard_gang(wl.state, new_devices)
        handle.attach(rj.alloc, devices=new_devices)
        wl.bind(handle)

    def _on_finish(self, rj) -> None:
        job_id = rj.job.job_id
        handle = self.handles[job_id]
        while not self.workloads[job_id].done:
            self._step_gang(job_id)   # drain the gang's remaining steps
        handle.detach()               # loop releases engine accounting
        handle.status = "released"
        self.fabric.gangs.pop(job_id, None)
        rec = self._record(job_id)
        rec["final_metrics"] = rec.pop("metrics", {})

    # ---- fleet-churn hooks (core.fleet events, live) -----------------------
    def _on_join(self, ev, new_hosts) -> None:
        # engine capacity is already in (the loop's FleetController);
        # back the new hosts with staged spare devices
        caps = [int(c) for c in ev.capacities]
        devices = self.fabric.take_spares(sum(caps))
        self.fabric._pool_add_hosts(devices, caps)

    def _on_drain(self, ev) -> None:
        self.fabric.mark_draining(ev.hosts)

    def _on_hosts_down(self, hosts) -> None:
        self.fabric.fail_hosts_pool(hosts)

    def _on_checkpoint(self, rj) -> None:
        job_id = rj.job.job_id
        wl = self.workloads[job_id]
        handle = self.handles[job_id]
        snap = handle.checkpoint(wl.state, wl.steps_done)
        stat = handle.ckpt_stats[-1]
        rec = self._record(job_id)
        rec["checkpoints"] = rec.get("checkpoints", 0) + 1
        rec["last_ckpt_fingerprint"] = snap.fingerprint
        if stat["kind"] == "delta":
            rec["delta_checkpoints"] = rec.get("delta_checkpoints", 0) + 1
        rec["ckpt_bytes"] = rec.get("ckpt_bytes", 0) + stat["bytes"]
        rec["ckpt_full_bytes"] = (rec.get("ckpt_full_bytes", 0)
                                  + stat["full_bytes"])
        # measured bytes feed calibration stats only — the trace keeps
        # charging the configured fraction so Action logs stay
        # identical to predict_trace
        self.model.observe_checkpoint(stat["bytes"], stat["full_bytes"])
        if self.adapt_cadence and self.checkpoint_interval is not None \
                and len(self.model.ckpt_observed) \
                % self.model.ckpt_rebase_every == 0:
            # rebase window closed: fold the *measured* delta fraction
            # into the Young/Daly interval (tau* ∝ sqrt(delta))
            frac = self.model.observed_delta_fraction()
            eff0 = self.model.effective_checkpoint_cost_s()
            if frac is not None and eff0 > 0:
                eff = self.model.effective_checkpoint_cost_s(
                    fraction=frac)
                self.checkpoint_interval = float(
                    self._tau0 * np.sqrt(eff / eff0))
                rec["adapted_interval_s"] = self.checkpoint_interval

    def _on_shrink(self, rj, survivors) -> None:
        # shrink-before-rollback (or a regrow back to full width),
        # live: the event loop already settled engine accounting
        # (apply_migration mid-drain, bind after a hard fail) and
        # rj.alloc carries the new placement.  State is replicated
        # across the gang, so any surviving replica reshards it onto
        # the new devices with nothing lost; dead and draining devices
        # are dropped by the pool's reclaim.
        job_id = rj.job.job_id
        handle = self.handles[job_id]
        wl = self.workloads[job_id]
        old_width = len(handle.devices)
        self.fabric.reclaim(handle.devices)
        new_devices = self.fabric.claim(rj.alloc.placement)
        wl.state, _ = elastic_mod.reshard_gang(wl.state, new_devices)
        handle.attach(rj.alloc, devices=new_devices)
        self.fabric.gangs[job_id] = handle
        wl.bind(handle)
        rec = self._record(job_id)
        key = "shrinks" if len(new_devices) < old_width else "regrows"
        rec[key] = rec.get(key, 0) + 1
        rec["epochs"].append(handle.group.epoch)

    def _on_fail(self, rj, hosts) -> None:
        # the gang's host died: live state is gone; fall back to the
        # last real snapshot (engine accounting already settled by
        # fail_hosts; the loop requeues the job and the resumed start
        # restores bit-exactly via handle.resume)
        job_id = rj.job.job_id
        handle = self.handles[job_id]
        wl = self.workloads[job_id]
        handle.fail(hosts)
        self.fabric.gangs.pop(job_id, None)
        wl.state = None               # lives in the snapshot until resume
        wl.steps_done = handle.snapshot.step
        rec = self._record(job_id)
        rec["failures"] = rec.get("failures", 0) + 1
