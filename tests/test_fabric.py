"""Multi-tenant Fabric tests: gang lifecycle, priority preemption with
bit-exact resume, concurrent gangs, and trace-driven live execution
matching the simulator's prediction.

Fast tests exercise the pure pieces (PreemptPolicy, GranuleGroup queue
survival, device-pool accounting); the heavy end-to-end paths run in
subprocesses with an 8-device CPU fabric (same pattern as test_dist)."""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.granule import GranuleGroup
from repro.core.placement import PlacementEngine, PreemptPolicy

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# PreemptPolicy (pure)
# ---------------------------------------------------------------------------
def test_preemption_plan_evicts_lowest_priority_first():
    eng = PlacementEngine(2, 8)
    eng.allocate("low-big", 8)
    eng.allocate("mid", 4)
    eng.allocate("low-small", 4)
    pri = {"low-big": 0, "mid": 3, "low-small": 0}
    # 8 chips at priority 5: evicting the big low-priority gang suffices
    plan = eng.preemption_plan(8, 5, pri)
    assert plan == ["low-big"]
    # 14 chips: both low-priority gangs go before the mid one
    plan = eng.preemption_plan(14, 5, pri)
    assert plan is not None and "mid" not in plan[:2] \
        and set(plan) >= {"low-big", "low-small"}
    # nothing outranked: a priority-0 arrival cannot evict anyone
    assert eng.preemption_plan(4, 0, pri) is None
    # already placeable -> empty plan
    eng.release(eng.allocations["low-small"])
    assert eng.preemption_plan(2, 5, pri) == []


def test_preemption_plan_respects_max_victims():
    eng = PlacementEngine(2, 4)
    for i in range(4):
        eng.allocate(f"j{i}", 2)
    pri = {f"j{i}": 0 for i in range(4)}
    assert eng.preemption_plan(8, 1, pri, preempt=PreemptPolicy(
        max_victims=1)) is None
    plan = eng.preemption_plan(8, 1, pri)
    assert plan is not None and len(plan) == 4


def test_engine_ragged_capacities():
    eng = PlacementEngine(3, 4, capacities=[4, 4, 2])
    assert eng.total_chips == 10
    a = eng.allocate("j", 10)
    assert a is not None and a.n == 10
    eng.release(a)
    assert eng.idle_chips() == 10


def test_infer_host_speeds_uniform_pool_is_homogeneous():
    from repro.core.fabric import infer_host_speeds

    class Dev:
        def __init__(self, kind):
            self.device_kind = kind

    # uniform pool (whatever the generation): no speeds, homogeneous path
    assert infer_host_speeds([Dev("TPU v4")] * 6, 2) is None
    # mixed generations: per-host means over the shared host map
    devs = [Dev("TPU v4")] * 2 + [Dev("TPU v2")] * 2 + [Dev("TPU v4")]
    speeds = infer_host_speeds(devs, 2)
    assert speeds == [0.75, 0.25, 0.75]     # ragged last host included


def test_join_hosts_infers_generation_speeds():
    from repro.core.fabric import Fabric

    class Dev:
        def __init__(self, kind):
            self.device_kind = kind

    # an older-generation host joining a uniform fleet re-opens the
    # heterogeneous path at its relative speed
    fab = Fabric(devices=[Dev("TPU v5")] * 4, chips_per_host=2)
    assert fab.engine.speeds is None
    new = fab.join_hosts([Dev("TPU v2")] * 2)
    assert new == [2]
    assert list(fab.engine.speeds) == [1.0, 1.0, 0.25]
    assert fab.engine.heterogeneous
    # same-generation joiners keep the uniform fast path (relative 1.0
    # even when the shared generation is not the newest)
    fab2 = Fabric(devices=[Dev("TPU v4")] * 4, chips_per_host=2)
    fab2.join_hosts([Dev("TPU v4")] * 2)
    assert fab2.engine.speeds is None and fab2.engine.hosts == 3
    # joining an already-heterogeneous fleet uses absolute factors
    fab3 = Fabric(devices=[Dev("TPU v5")] * 2 + [Dev("TPU v3")] * 2,
                  chips_per_host=2)
    fab3.join_hosts([Dev("TPU v4")] * 2)
    assert list(fab3.engine.speeds) == [1.0, 0.45, 0.75]


def test_fabric_pool_churn_drops_doomed_devices():
    from repro.core.fabric import Fabric

    class Dev:
        def __init__(self, i):
            self.i = i

    devs = [Dev(i) for i in range(6)]
    fab = Fabric(devices=devs, chips_per_host=2)
    taken = fab.claim([(0, 2), (1, 1)])
    fab.mark_draining([1])
    assert fab._free[1] == []            # free chips surrendered
    fab.reclaim(taken)
    assert fab._free[0] == devs[0:2]     # host-0 devices return
    assert fab._free[1] == []            # draining-host device dropped
    fab.fail_hosts_pool([2])
    assert fab._free[2] == [] and 2 in fab._retired_hosts


# ---------------------------------------------------------------------------
# GranuleGroup: in-place re-address keeps queues + epoch (paper Fig 8)
# ---------------------------------------------------------------------------
def test_readdress_preserves_group_identity_and_epoch():
    g = GranuleGroup("j", 4, [(i // 2, None) for i in range(4)])
    g.send(0, 3, {"tok": 1})
    # barrier precondition (paper §5.2): the message plane must be empty
    with pytest.raises(RuntimeError):
        g.readdress([(1, None)] * 4)
    assert g.recv(3, 0) == {"tok": 1}
    e0 = g.epoch
    granules_before = g.granules
    g.readdress([((i + 1) % 2, None) for i in range(4)])
    # in-place: granule identity survives (the old rebuild-from-scratch
    # path silently discarded queues and reset the epoch to 0)
    assert g.granules is granules_before
    assert g.epoch == e0 + 1
    assert g.address_table() == {0: 1, 1: 0, 2: 1, 3: 0}
    # messaging still works across the move, addressed by rank
    g.send(1, 2, "post-move")
    assert g.recv(2, 1) == "post-move"
    # no-op readdress does not burn an epoch
    g.readdress([((i + 1) % 2, None) for i in range(4)])
    assert g.epoch == e0 + 1


def test_resize_keeps_surviving_rank_queues():
    g = GranuleGroup("j", 4, [(0, None)] * 4)
    g.send(0, 1, "in-flight")
    with pytest.raises(RuntimeError):           # resize is a barrier too
        g.resize([(0, None)] * 2)
    assert g.recv(1, 0) == "in-flight"
    e0 = g.epoch
    g.resize([(0, None), (1, None)])            # shrink 4 -> 2
    assert g.size == 2 and g.epoch == e0 + 1
    g.send(1, 0, "post")
    assert g.recv(0, 1) == "post"
    e1 = g.epoch
    g.resize([(h, None) for h in (0, 0, 1, 1, 2, 2)])   # grow 2 -> 6
    assert g.size == 6 and g.epoch == e1 + 1
    assert g.granules[5].index == 5 and g.pending(5) == 0
    assert g.leader_of(2) == 4


# ---------------------------------------------------------------------------
# Live fabric (subprocess, 8 devices)
# ---------------------------------------------------------------------------
def test_preemption_evicts_checkpoints_and_resumes_bit_exact():
    print(run_sub("""
        import numpy as np
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.runtime.gang_workloads import TrainWorkload

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

        def steps(wl, handle, k):
            for _ in range(k):
                wl.run_step(handle)

        # reference: uninterrupted 6-step run on a whole-fabric gang
        fab = Fabric(chips_per_host=2)
        h = fab.allocate("ref", 8)
        ref = TrainWorkload(cfg, ocfg, dcfg, total_steps=6)
        ref.bind(h); ref.init_state(h); steps(ref, h, 6)
        h.release()
        assert fab.idle_chips() == 8

        # interrupted: 3 steps, then a high-priority arrival forces
        # preempt (checkpoint + release); the victim resumes bit-exactly
        low = fab.allocate("low", 8, priority=0)
        wl = TrainWorkload(cfg, ocfg, dcfg, total_steps=6)
        wl.bind(low); wl.init_state(low); steps(wl, low, 3)
        victims = fab.preemption_plan(4, priority=5)
        assert victims == ["low"], victims
        snap = low.preempt(wl.state, wl.steps_done)
        assert fab.idle_chips() == 8 and low.status == "preempted"
        hi = fab.allocate("hi", 4, priority=5)
        hiwl = TrainWorkload(cfg, ocfg, dcfg, total_steps=2)
        hiwl.bind(hi); hiwl.init_state(hi); steps(hiwl, hi, 2)
        hi.release()
        state, step = low.resume()          # fingerprint-verified restore
        assert step == 3 and low.status == "running"
        wl.state = state; wl.bind(low)
        steps(wl, low, 3)
        np.testing.assert_allclose(ref.losses, wl.losses, atol=1e-6)
        low.release()
        assert fab.idle_chips() == 8 and not fab.gangs
        print("preempt-resume-ok", wl.losses)
    """))


def test_concurrent_train_and_serve_gangs_share_fabric():
    print(run_sub("""
        import numpy as np, jax
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.runtime.gang_workloads import ServeWorkload, TrainWorkload

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

        fab = Fabric(chips_per_host=2)
        a = fab.allocate("train0", 4, priority=0)
        b = fab.allocate("serve0", 2, priority=1)
        assert a is not None and b is not None
        assert not (set(a.devices) & set(b.devices))
        assert fab.idle_chips() == 2
        ta = TrainWorkload(cfg, ocfg, dcfg, total_steps=3)
        ta.bind(a); ta.init_state(a)
        sb = ServeWorkload(cfg, prompt_len=8, new_tokens=3, batch=2,
                           max_len=16)
        sb.bind(b); sb.init_state(b)
        # interleave the two gangs step by step on one fabric
        while not (ta.done and sb.done):
            if not ta.done: ta.run_step(a)
            if not sb.done: sb.run_step(b)
        outs = [r.out for r in sb.requests]
        assert all(len(o) == 3 for o in outs), outs
        assert len(ta.losses) == 3 and np.isfinite(ta.losses).all()
        a.release(); b.release()
        assert fab.idle_chips() == 8 and not fab.gangs
        print("concurrent-ok", ta.losses, outs)
    """))


def test_shared_fabric_rescale_caps_and_serve_resume_fresh_loop():
    print(run_sub("""
        import numpy as np, jax
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.placement import LocalityScoredPolicy
        from repro.core.simulator import Job
        from repro.models import transformer as tf
        from repro.runtime.gang_workloads import workload_factory
        from repro.runtime.serve_loop import Request, ServeLoop
        from repro.runtime.train_loop import (FaabricTrainRuntime,
                                              RuntimeConfig)

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

        # a scheduled rescale beyond shared-fabric capacity is skipped
        # (other tenants' chips are not ours to take), not a crash
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=12)
        fab = Fabric(chips_per_host=2)
        rt = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=4, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-shresc/a", rescale_at={2: 8}),
            devices=fab.devices[2:8], fabric=fab, job_id="t0")
        other = fab.allocate("tenant", 2, priority=1)
        out = rt.run(seed=0)[1]
        assert out["rescales"] == 0 and len(rt.devices) == 6
        rt.release(); other.release()
        # ...but a placeable partial grow (4 -> world+idle = 6) fires
        fab = Fabric(chips_per_host=2)
        rt = FaabricTrainRuntime(cfg, ocfg, dcfg, RuntimeConfig(
            total_steps=4, checkpoint_every=100,
            ckpt_dir="/tmp/repro-t-shresc/b", rescale_at={2: 8}),
            devices=fab.devices[:4], fabric=fab, job_id="t1")
        other = fab.allocate("tenant", 2)
        out = rt.run(seed=0)[1]
        assert out["rescales"] == 1 and len(rt.devices) == 6
        rt.release(); other.release()
        assert fab.idle_chips() == 8
        print("shared-rescale-ok")

        # run_trace with an explicit policy must not overwrite the
        # fabric engine's configured default
        fab2 = Fabric(chips_per_host=2, policy="locality")
        before = fab2.engine.default_policy
        fab2.run_trace([Job("a", "mpi-compute", 2, 50.0,
                            workload="train")],
                       workload_factory(cfg, ocfg, dcfg, train_steps=1),
                       policy="binpack")
        assert fab2.engine.default_policy is before
        assert isinstance(before, LocalityScoredPolicy)
        print("policy-unmutated-ok")

        # a serving snapshot restores into a FRESH ServeLoop (new driver
        # process): host-side request bookkeeping rides in the snapshot
        params = jax.jit(lambda k: tf.init_params(k, cfg))(
            jax.random.PRNGKey(0))
        mk = lambda: [Request(rid=i,
                              prompt=np.asarray([1,2,3,4,5,6,7,8],
                                                np.int32),
                              max_new_tokens=6) for i in range(2)]
        ref = [r.out for r in ServeLoop(cfg, params, max_len=32).run(mk())]
        l1 = ServeLoop(cfg, params, max_len=32)
        l1.start(mk()); l1.decode_step(); l1.decode_step()
        snap = l1.serve_state()
        l2 = ServeLoop(cfg, params, max_len=32)
        l2.load_serve_state(snap)
        rebuilt = l2._reqs                  # drained to None on finish
        assert rebuilt is not None and not l2.done
        while l2.decode_step():
            pass
        assert [r.out for r in rebuilt] == ref
        print("fresh-serve-resume-ok")
    """))


def test_hetero_fabric_run_trace_matches_prediction():
    # mixed-generation fleet acceptance: a Fabric with per-host speeds
    # (half the hosts at s=0.5) runs a real train/serve trace whose
    # completion order matches predict_trace under the same
    # heterogeneous capacities/speeds — and placements favour the fast
    # generation for the compute-bound gang
    print(run_sub("""
        import numpy as np
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.simulator import Job, hetero_speeds
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)

        # 8 devices, 2 chips/host -> 4 hosts; hosts 0-1 old generation
        speeds = hetero_speeds(4, slow_fraction=0.5, slow=0.5)
        fab = Fabric(chips_per_host=2, policy="locality",
                     speeds=list(speeds))
        assert fab.engine.heterogeneous
        jobs = [
            Job("train-net", "mpi-network", 4, 120.0, arrival=0.0,
                priority=0, workload="train"),
            Job("train-cmp", "mpi-compute", 4, 120.0, arrival=0.0,
                priority=0, workload="train"),
            Job("serve-0", "omp", 2, 60.0, arrival=1.0, priority=1,
                workload="serve"),
        ]
        pred = fab.predict_trace(jobs, preempt=True)
        starts = {a.payload["job"]: a.payload["placement"]
                  for a in pred.actions if a.kind == "start"}
        # first-placed network gang takes the fast hosts whole; the
        # compute gang then splits across the slow generation
        fast = {h for h, s in enumerate(speeds) if s == 1.0}
        assert {h for h, _ in starts["train-net"]} <= fast, starts
        ex = fab.run_trace(jobs, workload_factory(cfg, ocfg, dcfg,
                                                  train_steps=3,
                                                  serve_tokens=3),
                           preempt=True)
        assert ex.result.finish_order == pred.finish_order, (
            ex.result.finish_order, pred.finish_order)
        live_starts = {a.payload["job"]: a.payload["placement"]
                       for a in ex.result.actions if a.kind == "start"}
        assert live_starts == starts      # placement-for-placement
        assert fab.idle_chips() == fab.engine.total_chips
        print("hetero-trace-ok", ex.result.finish_order)
    """))


def test_sharded_fabric_run_trace_matches_prediction():
    # acceptance: a Fabric built over a ShardedPlacementEngine executes
    # a real trace whose completion order matches predict_trace (the
    # clone keeps the sharded architecture), and a single-shard fabric
    # is placement-for-placement identical to the centralised one
    print(run_sub("""
        from repro.core.fabric import Fabric
        from repro.core.placement import ShardedPlacementEngine
        from repro.core.simulator import Job
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        jobs = [
            Job("train-low", "mpi-compute", 6, 300.0, arrival=0.0,
                priority=0, workload="train"),
            Job("serve-0", "omp", 2, 120.0, arrival=0.0, priority=1,
                workload="serve"),
            Job("train-hi", "mpi-compute", 6, 150.0, arrival=3.0,
                priority=5, workload="train"),
        ]
        # 8 devices, 2 chips/host -> 4 hosts in 2 shards of 2
        fab = Fabric(chips_per_host=2, shard_hosts=2)
        assert isinstance(fab.engine, ShardedPlacementEngine)
        assert fab.engine.n_shards == 2
        pred = fab.predict_trace(jobs, preempt=True)
        assert pred.preemptions >= 1
        ex = fab.run_trace(jobs, workload_factory(cfg, ocfg, dcfg,
                                                  train_steps=3,
                                                  serve_tokens=3),
                           preempt=True)
        assert ex.result.finish_order == pred.finish_order, (
            ex.result.finish_order, pred.finish_order)
        assert ex.result.preemptions == pred.preemptions
        assert fab.idle_chips() == fab.engine.total_chips
        print("sharded-trace-ok", ex.result.finish_order)

        # single shard covering the fleet == centralised, live
        one = Fabric(chips_per_host=2, shard_hosts=4)
        central = Fabric(chips_per_host=2)
        p1 = one.predict_trace(jobs, preempt=True)
        p2 = central.predict_trace(jobs, preempt=True)
        assert p1.actions == p2.actions
        print("single-shard-parity-ok")
    """))


def test_fleet_churn_hard_fail_resumes_bit_exact_live():
    # fleet-churn acceptance: a running gang's host hard-fails mid-run;
    # live execution rolls it back to its last real snapshot and resumes
    # bit-exactly (fingerprint-verified), the trace Action log matches
    # predict_trace event-for-event (central AND sharded), a reclaim
    # drains gracefully through the evacuation planner, and a join pulls
    # staged spare devices into the pool
    print(run_sub("""
        import jax
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.fleet import FleetEvent
        from repro.core.simulator import Job
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        jobs = [
            Job("train-a", "mpi-compute", 4, 200.0, arrival=0.0,
                workload="train"),
            Job("serve-0", "omp", 2, 120.0, arrival=0.0, priority=1,
                workload="serve"),
        ]
        devs = jax.devices()
        # 6 devices in the fabric (3 hosts of 2), 2 staged as spares
        events = [FleetEvent(6.0, "fail", hosts=[0]),
                  FleetEvent(10.0, "join", capacities=[2])]
        for shard_hosts in (None, 2):
            fab = Fabric(devices=devs[:6], chips_per_host=2,
                         shard_hosts=shard_hosts, spares=devs[6:])
            pred = fab.predict_trace(jobs, preempt=True,
                                     fleet_events=events,
                                     checkpoint_interval=4.0)
            assert pred.recoveries >= 1, pred.recoveries
            ex = fab.run_trace(
                jobs, workload_factory(cfg, ocfg, dcfg, train_steps=3,
                                       serve_tokens=3),
                preempt=True, fleet_events=events,
                checkpoint_interval=4.0)
            res = ex.result
            # live Action log == simulated Action log, event for event
            assert res.actions == pred.actions
            assert res.recoveries == pred.recoveries >= 1
            assert res.finish_order == pred.finish_order
            # the failed gang took real checkpoints, lost its host, and
            # resumed bit-exactly (resume() fingerprint-verifies)
            victim = next(a.payload["job"] for a in res.actions
                          if a.kind == "recover")
            rec = ex.live[victim]
            assert rec["failures"] >= 1
            assert rec["checkpoints"] >= 1
            assert rec["resumes_verified"] >= 1
            assert ex.live[victim]["steps"] >= 3
            # every job still finished on the churned fleet
            assert set(res.finish_order) == {j.job_id for j in jobs}
            label = "central" if shard_hosts is None else "sharded"
            print(f"churn-fail-{label}-ok", res.finish_order)

        # graceful reclaim: with free capacity elsewhere, the drained
        # gang evacuates through the planner (live reshard, no rollback)
        small = [Job("train-a", "mpi-compute", 2, 150.0, arrival=0.0,
                     workload="train"),
                 Job("serve-0", "omp", 2, 120.0, arrival=0.0,
                     priority=1, workload="serve")]
        fab = Fabric(devices=devs[:6], chips_per_host=2,
                     spares=devs[6:])
        events = [FleetEvent(5.0, "reclaim", hosts=[2], drain_s=30.0)]
        pred = fab.predict_trace(small, preempt=True,
                                 fleet_events=events)
        ex = fab.run_trace(
            small, workload_factory(cfg, ocfg, dcfg, train_steps=3,
                                    serve_tokens=3),
            preempt=True, fleet_events=events)
        assert ex.result.actions == pred.actions
        assert ex.result.evacuations == pred.evacuations >= 1
        assert ex.result.recoveries == 0
        assert set(ex.result.finish_order) == {j.job_id for j in small}
        print("churn-drain-ok", ex.result.evacuations)
    """))


def test_run_trace_preempts_and_matches_simulator_prediction():
    # the acceptance trace: >=2 priority classes, a preemption with
    # bit-exact resume, a concurrent train+serve pair, and live per-job
    # completion order == the simulator's prediction under one policy
    print(run_sub("""
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.simulator import Job
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        jobs = [
            Job("train-low", "mpi-compute", 6, 300.0, arrival=0.0,
                priority=0, workload="train"),
            Job("serve-0", "omp", 2, 120.0, arrival=0.0, priority=1,
                workload="serve"),
            Job("train-hi", "mpi-compute", 6, 150.0, arrival=3.0,
                priority=5, workload="train"),
        ]
        fab = Fabric(chips_per_host=2)
        pred = fab.predict_trace(jobs, preempt=True)
        assert pred.preemptions >= 1
        ex = fab.run_trace(jobs, workload_factory(cfg, ocfg, dcfg,
                                                  train_steps=3,
                                                  serve_tokens=3),
                           preempt=True)
        res = ex.result
        assert res.finish_order == pred.finish_order, (
            res.finish_order, pred.finish_order)
        assert res.preemptions == pred.preemptions >= 1
        assert ex.live["train-low"]["preemptions"] >= 1
        assert ex.live["train-low"]["resumes_verified"] >= 1
        kinds = {j: r["workload"] for j, r in ex.live.items()}
        assert kinds["serve-0"] == "ServeWorkload"
        assert kinds["train-hi"] == "TrainWorkload"
        ms = ex.job_makespans(jobs)
        assert set(ms) == {j.job_id for j in jobs}
        assert all(v > 0 for v in ms.values())
        # the preemptor finished first despite arriving last
        assert res.finish_order[0] == "train-hi"
        assert fab.idle_chips() == fab.engine.total_chips
        assert not fab.gangs
        print("trace-acceptance-ok", res.finish_order, ms)
    """))


def test_run_trace_delta_checkpoints_match_prediction():
    # delta-everything data plane (ISSUE 6): with a configured delta
    # fraction the simulator charges cheaper non-rebase checkpoints,
    # the live gang ships diffsync chains, a hard failure replays
    # base+deltas bit-exactly, and live Action logs still match the
    # prediction event for event
    print(run_sub("""
        import jax
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.fleet import FleetEvent
        from repro.core.placement import CostModel
        from repro.core.simulator import Job
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        jobs = [Job("train-a", "mpi-compute", 4, 40.0, arrival=0.0,
                    workload="train")]
        devs = jax.devices()
        events = [FleetEvent(6.0, "fail", hosts=[1]),
                  FleetEvent(10.0, "join", capacities=[2])]
        fab = Fabric(devices=devs[:6], chips_per_host=2, spares=devs[6:])
        cm = fab.engine.cost_model
        cm.ckpt_delta_fraction = 0.1
        cm.ckpt_rebase_every = 4
        pred = fab.predict_trace(jobs, preempt=True, fleet_events=events,
                                 checkpoint_interval=2.0)
        assert pred.recoveries >= 1
        ex = fab.run_trace(
            jobs, workload_factory(cfg, ocfg, dcfg, train_steps=3,
                                   serve_tokens=3),
            preempt=True, fleet_events=events, checkpoint_interval=2.0)
        res = ex.result
        assert res.actions == pred.actions
        assert res.recoveries == pred.recoveries >= 1
        rec = ex.live["train-a"]
        # the gang shipped real deltas and recovered through the chain
        assert rec.get("delta_checkpoints", 0) >= 1, rec
        assert rec["ckpt_bytes"] < rec["ckpt_full_bytes"], rec
        assert rec["resumes_verified"] >= 1
        frac = cm.observed_delta_fraction()
        assert frac is not None and 0 < frac < 1.0
        print("delta-live-ok", rec["checkpoints"],
              rec["delta_checkpoints"], round(frac, 4))
    """))


def test_shrink_before_rollback_live_matches_prediction():
    # risk-aware recovery, live: a rack fail strands the training gang;
    # instead of rolling back it reshards onto surviving chips (live
    # reshard from a replica, no snapshot restore), then regrows to its
    # submitted width when the replacement host joins — Action log
    # bit-identical to predict_trace throughout
    print(run_sub("""
        import jax
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.fleet import FleetEvent
        from repro.core.placement import CostModel
        from repro.core.simulator import Job
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=20)
        jobs = [
            Job("train-a", "mpi-compute", 4, 200.0, arrival=0.0,
                workload="train"),
            Job("serve-0", "omp", 2, 120.0, arrival=0.0, priority=1,
                workload="serve"),
        ]
        devs = jax.devices()
        events = [FleetEvent(6.0, "fail", hosts=[0]),
                  FleetEvent(10.0, "join", capacities=[2])]
        fab = Fabric(devices=devs[:6], chips_per_host=2,
                     spares=devs[6:],
                     cost_model=CostModel(risk_tau_s=4.0))
        pred = fab.predict_trace(jobs, fleet_events=events,
                                 checkpoint_interval=4.0,
                                 shrink_recovery=True)
        assert pred.shrinks >= 1 and pred.regrows >= 1, \\
            (pred.shrinks, pred.regrows)
        assert pred.recoveries == 0
        ex = fab.run_trace(
            jobs, workload_factory(cfg, ocfg, dcfg, train_steps=3,
                                   serve_tokens=3),
            fleet_events=events, checkpoint_interval=4.0,
            shrink_recovery=True)
        res = ex.result
        assert res.actions == pred.actions
        assert res.shrinks == pred.shrinks
        assert res.regrows == pred.regrows
        assert res.recoveries == 0 and res.lost_work_s == 0.0
        assert res.finish_order == pred.finish_order
        rec = ex.live["train-a"]
        assert rec.get("shrinks", 0) >= 1
        assert rec.get("regrows", 0) >= 1
        assert rec["steps"] >= 3          # training completed resharded
        assert set(res.finish_order) == {j.job_id for j in jobs}
        print("shrink-live-ok", res.shrinks, res.regrows)
    """))


def test_adaptive_cadence_rederives_interval_from_observed_deltas():
    # satellite: the live runner folds the observed delta fraction into
    # the Young/Daly cadence after each rebase window — tau tightens by
    # sqrt(eff_observed / eff_configured) when deltas run cheap
    print(run_sub("""
        from repro.configs.registry import reduced_config
        from repro.data.pipeline import DataConfig
        from repro.optim.adamw import AdamWConfig
        from repro.core.fabric import Fabric
        from repro.core.placement import CostModel
        from repro.core.simulator import Job
        from repro.runtime.gang_workloads import workload_factory

        cfg = reduced_config("llama3.2-1b").with_(n_layers=1, vocab=128)
        dcfg = DataConfig(vocab=128, seq_len=8, global_batch=8)
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
        jobs = [Job("train-a", "mpi-compute", 4, 400.0, arrival=0.0,
                    workload="train")]
        cm = CostModel(ckpt_rebase_every=3)
        fab = Fabric(chips_per_host=2, cost_model=cm)
        ex = fab.run_trace(
            jobs, workload_factory(cfg, ocfg, dcfg, train_steps=10),
            checkpoint_interval=8.0, adapt_cadence=True)
        rec = ex.live["train-a"]
        assert rec["checkpoints"] >= 3
        frac = cm.observed_delta_fraction()
        assert frac is not None and 0.0 < frac < 1.0
        # the interval was re-derived and recorded, and it tightened
        # (observed deltas are cheaper than the configured full cost);
        # tau = tau0 * sqrt(eff/eff0) with the fraction observed at the
        # rebase window, so the implied effective cost sits between the
        # all-delta floor and the configured full cost
        assert "adapted_interval_s" in rec, sorted(rec)
        tau = rec["adapted_interval_s"]
        assert 0.0 < tau < 8.0
        eff0 = cm.effective_checkpoint_cost_s()
        implied = eff0 * (tau / 8.0) ** 2
        assert cm.effective_checkpoint_cost_s(fraction=0.0) \\
            <= implied <= eff0
        print("adapt-cadence-ok", round(tau, 3))
    """))
