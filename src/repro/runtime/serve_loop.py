"""Batched serving runtime: continuous prefill + decode with KV caches.

Requests carry a prompt; the runtime batches admitted requests, prefills
them (building decode state), then decodes one token per step for the whole
batch.  Serving gangs are Granule groups like training gangs, so migration
works the same way: decode state is the snapshot (a KV cache is just more
shared state to diff — paper §4 applies unchanged).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import model as model_mod
from repro.models import transformer as tf


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (prompt_len,) int32
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)


@dataclasses.dataclass
class ServeStats:
    prefill_tokens: int = 0
    decoded_tokens: int = 0
    steps: int = 0


class ServeLoop:
    """Fixed-batch serving of equal-length prompts (greedy decoding)."""

    def __init__(self, cfg: ArchConfig, params, max_len: int = 256,
                 window: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.window = window
        self._prefill = jax.jit(model_mod.make_prefill_step(cfg,
                                                            window=window))
        self._serve = jax.jit(model_mod.make_serve_step(cfg, window=window))
        self.stats = ServeStats()

    def _pad_states(self, states, prompt_len: int):
        """Grow prefill KV caches to max_len-sized decode buffers."""
        size = min(self.max_len, self.window) if self.window else self.max_len

        def pad(x):
            if x.ndim == 5 and x.shape[2] == prompt_len:  # (P,B,S,kv,hd)
                if size <= prompt_len:
                    return x[:, :, -size:]
                pad_spec = [(0, 0)] * x.ndim
                pad_spec[2] = (0, size - prompt_len)
                return jnp.pad(x, pad_spec)
            return x
        return [jax.tree.map(pad, s) for s in states]

    def run(self, requests: Sequence[Request],
            extras: Optional[Dict[str, Any]] = None) -> List[Request]:
        reqs = list(requests)
        b = len(reqs)
        plen = len(reqs[0].prompt)
        assert all(len(r.prompt) == plen for r in reqs), "equal-length batch"
        tokens = jnp.asarray(np.stack([r.prompt for r in reqs]), jnp.int32)
        batch = {"tokens": tokens, **(extras or {})}
        last_logits, states = self._prefill(self.params, batch)
        self.stats.prefill_tokens += b * plen
        states = self._pad_states(states, plen)
        cur = jnp.argmax(last_logits[:, 0], axis=-1).astype(jnp.int32)
        max_new = max(r.max_new_tokens for r in reqs)
        for t in range(max_new):
            for i, r in enumerate(reqs):
                if t < r.max_new_tokens:
                    r.out.append(int(cur[i]))
            pos = jnp.full((b, 1), plen + t, jnp.int32)
            logits, states = self._serve(self.params, states,
                                         cur[:, None], pos)
            cur = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
            self.stats.decoded_tokens += b
            self.stats.steps += 1
        return reqs
