"""llama-3.2-vision-11b: 40L d4096 32H (GQA kv=8) d_ff=14336 vocab=128256.

Cross-attn image layers every 5th layer; vision tower is a STUB --
input_specs() provides precomputed patch embeddings (B, 1601, d_model).
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=128256,
    cross_attn_every=5,
    n_img_tokens=1601,
    rope_theta=500_000.0,
)
