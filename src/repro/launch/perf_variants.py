import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

"""§Perf hillclimb driver: compile config variants of the three chosen
cells and record the roofline-term deltas (results/perf_iterations.json).

Variants per cell:
  baseline      paper-faithful reference path (f32 TP reductions)
  bf16_reduce   row-parallel partial sums in bf16 (iteration #7)
"""
import json
import time

from repro.configs.base import SHAPES
from repro.launch import dryrun as dr
from repro.launch import hloanalysis

CELLS = [
    ("llama3.2-1b", "train_4k"),
    ("granite-moe-1b-a400m", "train_4k"),
    ("xlstm-1.3b", "train_4k"),
]

OUT = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                   "results", "perf_iterations.json")


def measure(arch: str, shape_name: str, **overrides):
    """Difference-method analysis measurement with config overrides."""
    shape = SHAPES[shape_name]
    mesh = dr.make_production_mesh()
    cfg = dr.dryrun_config(arch, deploy=False).with_(**overrides)
    period_len = len(cfg.period())
    n_per = cfg.n_periods()
    ms = []
    for k in (1, 2):
        cfg_k = cfg.with_(n_layers=period_len * k)
        compiled, _, _ = dr._compile(cfg_k, shape, mesh)
        cost = compiled.cost_analysis()
        ana = hloanalysis.analyze(compiled.as_text())
        ms.append((float(cost.get("flops", 0.0)), ana))
        del compiled
    extrap = lambda a, b: max(0.0, a + (n_per - 1) * (b - a))
    flops = extrap(ms[0][0], ms[1][0])
    coll = {k: extrap(ms[0][1][k], ms[1][1][k]) for k in ms[1][1]}
    rl = dr.roofline({"flops": flops}, coll, cfg, shape, mesh.devices.size)
    return rl


def main():
    results = {}
    for arch, shape in CELLS:
        for name, overrides in (("baseline", {}),
                                ("bf16_tp_reduce", {"bf16_tp_reduce": True})):
            t0 = time.time()
            rl = measure(arch, shape, **overrides)
            key = f"{arch}/{shape}/{name}"
            results[key] = {
                "terms_s": rl["terms_s"],
                "bottleneck": rl["bottleneck"],
                "roofline_fraction": rl["roofline_fraction"],
                "collective_bytes": rl["per_device"]["collective_bytes"],
                "measure_s": round(time.time() - t0, 1),
            }
            print(key, json.dumps(results[key]))
    os.makedirs(os.path.dirname(os.path.abspath(OUT)), exist_ok=True)
    with open(OUT, "w") as f:
        json.dump(results, f, indent=1)


if __name__ == "__main__":
    main()
