"""Byte-wise-diff synchronisation of shared state (paper §4, Table 3).

Faabric tracks writes to shared pages with ``mprotect`` and ships byte-wise
diffs with *merge operations* back to the main snapshot.  On TPU there is no
page-fault hook inside an XLA program, so the TPU-native adaptation is
explicit **chunk-wise diffing**: every state leaf is viewed as a sequence of
fixed-size chunks (the page analogue); dirty chunks are found by comparing
against the parent snapshot, and only dirty chunks travel.

Two representations are provided:

* **sparse** (host-side; checkpointing, migration, cross-pod delta sync):
  per-leaf ``(chunk_idx, payload)`` arrays with dynamic length — exactly the
  paper's (offset, bytes) diff list;
* **dense-mask** (jit-side; in-graph reductions): (mask, delta) with static
  shapes, consumed by the ``kernels.diff_merge`` Pallas kernel.

Merge operations follow Table 3 exactly:
    sum        A1 = A0 + (B1 - B0)
    subtract   A1 = A0 - (B0 - B1)
    multiply   A1 = A0 * (B1 / B0)
    divide     A1 = A0 / (B0 / B1)
    overwrite  A1 = B1
where A0 = main-snapshot value, B0 = child's snapshot-at-fork value,
B1 = child's value after execution, A1 = merged main value.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

CHUNK = 1024  # elements per chunk (the "page" size of the diff protocol)

MERGE_OPS = ("sum", "subtract", "multiply", "divide", "overwrite")


def _as_f64(a):
    return np.asarray(a, dtype=np.float64)


def merge_scalarwise(a0, b0, b1, op: str):
    """Apply one Table-3 merge op elementwise (host/numpy)."""
    if op == "overwrite":
        return np.asarray(b1, dtype=np.asarray(a0).dtype)
    a0d, b0d, b1d = _as_f64(a0), _as_f64(b0), _as_f64(b1)
    if op == "sum":
        out = a0d + (b1d - b0d)
    elif op == "subtract":
        out = a0d - (b0d - b1d)
    elif op == "multiply":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b0d == 0, a0d, a0d * (b1d / b0d))
    elif op == "divide":
        with np.errstate(divide="ignore", invalid="ignore"):
            out = np.where(b1d == 0, a0d, a0d / (b0d / b1d))
    else:
        raise ValueError(op)
    return out.astype(np.asarray(a0).dtype)


# ---------------------------------------------------------------------------
# Sparse (host-side) diff lists — the migration/checkpoint wire format
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LeafDiff:
    """Diff of one state leaf: dirty chunk indices + their new contents."""
    idx: np.ndarray        # (k,) int32 dirty chunk indices
    new: np.ndarray        # (k, CHUNK) values after execution (B1)
    old: np.ndarray        # (k, CHUNK) values at fork (B0); merge ops need it
    shape: Tuple[int, ...]
    dtype: Any
    op: str = "overwrite"

    @property
    def nbytes(self) -> int:
        return int(self.idx.nbytes + self.new.nbytes
                   + (0 if self.op == "overwrite" else self.old.nbytes))


def _chunk_view(a: np.ndarray) -> np.ndarray:
    flat = np.ravel(a)
    pad = (-flat.size) % CHUNK
    if pad:
        flat = np.pad(flat, (0, pad))
    return flat.reshape(-1, CHUNK)


def diff_leaf(old: np.ndarray, new: np.ndarray, op: str = "overwrite"
              ) -> LeafDiff:
    """Chunk-wise compare ``new`` against the fork snapshot ``old``."""
    assert old.shape == new.shape and old.dtype == new.dtype
    oc, nc = _chunk_view(old), _chunk_view(new)
    dirty = np.any(oc != nc, axis=1)
    idx = np.nonzero(dirty)[0].astype(np.int32)
    return LeafDiff(idx=idx, new=nc[idx].copy(), old=oc[idx].copy(),
                    shape=old.shape, dtype=old.dtype, op=op)


def apply_leaf(main: np.ndarray, d: LeafDiff) -> np.ndarray:
    """Merge a LeafDiff into the main copy (A0 -> A1, Table 3)."""
    mc = _chunk_view(main).copy()
    mc[d.idx] = merge_scalarwise(mc[d.idx], d.old, d.new, d.op)
    return mc.reshape(-1)[: main.size].reshape(main.shape).astype(main.dtype)


def diff_tree(old_tree, new_tree, op: str = "overwrite") -> Dict[str, Any]:
    """Diff two state pytrees -> {path: LeafDiff} for dirty leaves only."""
    flat_old = jax.tree_util.tree_flatten_with_path(old_tree)[0]
    flat_new = jax.tree_util.tree_leaves(new_tree)
    diffs = {}
    for (path, o), n in zip(flat_old, flat_new):
        d = diff_leaf(np.asarray(o), np.asarray(n), op=op)
        if d.idx.size:
            diffs[jax.tree_util.keystr(path)] = d
    return diffs


def apply_tree(main_tree, diffs: Dict[str, Any]):
    """Merge a diff dict into the main pytree; returns the merged tree."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(main_tree)
    out = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        if key in diffs:
            out.append(apply_leaf(np.asarray(leaf), diffs[key]))
        else:
            out.append(np.asarray(leaf))
    return jax.tree_util.tree_unflatten(treedef, out)


def diff_nbytes(diffs: Dict[str, Any]) -> int:
    return sum(d.nbytes for d in diffs.values())


# ---------------------------------------------------------------------------
# Dense-mask (jit-side) diffs — consumed by kernels/diff_merge
# ---------------------------------------------------------------------------
def dense_diff(old, new):
    """jit-able chunk diff: returns (dirty_mask (nchunks,), delta) where
    delta = new - old (the merge-op payload for op=sum)."""
    flat_o = jnp.ravel(old)
    pad = (-flat_o.size) % CHUNK
    fo = jnp.pad(flat_o, (0, pad)).reshape(-1, CHUNK)
    fn = jnp.pad(jnp.ravel(new), (0, pad)).reshape(-1, CHUNK)
    mask = jnp.any(fo != fn, axis=1)
    return mask, (fn - fo)


def dense_merge(main, mask, payload, op: str = "sum"):
    """Merge a dense-mask diff into ``main`` (jit-able path).

    payload semantics: for op in {sum, subtract}: payload = B1 - B0;
    for overwrite: payload = B1; multiply/divide: payload = B1 / B0.
    """
    flat = jnp.ravel(main)
    pad = (-flat.size) % CHUNK
    fm = jnp.pad(flat, (0, pad)).reshape(-1, CHUNK).astype(jnp.float32)
    p = payload.astype(jnp.float32)
    if op == "sum":
        merged = fm + p
    elif op == "subtract":
        merged = fm - (-p)  # A1 = A0 - (B0 - B1) = A0 + (B1 - B0)
    elif op == "multiply":
        merged = fm * p
    elif op == "divide":
        merged = fm / jnp.where(p == 0, 1.0, p)
    elif op == "overwrite":
        merged = p
    else:
        raise ValueError(op)
    out = jnp.where(mask[:, None], merged, fm)
    return out.reshape(-1)[: flat.size].reshape(main.shape).astype(main.dtype)
