"""Cluster scheduler: chip-granular gang allocation (paper §3.4).

The paper's headline mechanism is scheduling threads/processes at vCPU
granularity onto shared VMs instead of dedicating whole VMs.  The TPU
adaptation schedules *Granules* (one per chip) onto shared hosts:

* ``alloc_granular`` — Faabric's policy: fill the host already running the
  job (locality), else the host with most free chips; a gang may fragment
  across hosts.
* ``alloc_slices``  — the fixed-slice baselines of §6.2: the cluster is
  pre-carved into slices of ``slice_size`` chips (the "k containers per VM"
  baselines); a job takes whole slices.
* ``migration_plan`` — at barrier control points, find fragmented gangs that
  now fit on fewer hosts and emit Granule moves (paper §3.3, Fig 8).

The same object drives the discrete-event simulator (paper Fig 10/11/14)
and the live runtime's sub-mesh carving on the CPU test fabric.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Allocation:
    job_id: str
    placement: List[Tuple[int, int]]        # [(host, n_chips)] sorted
    slice_size: int = 0                     # 0 = granular

    @property
    def n(self) -> int:
        return sum(c for _, c in self.placement)

    @property
    def hosts(self) -> List[int]:
        return [h for h, _ in self.placement]

    def fragmentation(self) -> int:
        return len(self.placement)

    def cross_host_fraction(self) -> float:
        """χ = P[two random ranks sit on different hosts] — the collective
        slow-path fraction used by the simulator's time model."""
        n = self.n
        if n <= 1:
            return 0.0
        return 1.0 - sum((c / n) ** 2 for _, c in self.placement)


class ClusterState:
    """Free-chip accounting for a cluster of identical hosts."""

    def __init__(self, hosts: int, chips_per_host: int):
        self.hosts = hosts
        self.chips_per_host = chips_per_host
        self.free = np.full(hosts, chips_per_host, dtype=np.int64)
        self.jobs_on_host: List[set] = [set() for _ in range(hosts)]

    # ---- capacity ----------------------------------------------------------
    @property
    def total_chips(self) -> int:
        return self.hosts * self.chips_per_host

    def idle_chips(self) -> int:
        return int(self.free.sum())

    def idle_fraction(self) -> float:
        return self.idle_chips() / self.total_chips

    # ---- granular (Faabric) policy -----------------------------------------
    def alloc_granular(self, job_id: str, n: int,
                       policy: str = "binpack") -> Optional[Allocation]:
        """Chip-granular gang allocation.

        binpack: prefer hosts with the *least* free chips that still help
        (dense packing); spread: most-free-first (load balancing);
        locality handled implicitly by taking the fewest hosts possible.
        """
        if n > self.idle_chips():
            return None
        if policy == "binpack":
            # fewest hosts: greedily take the most-free hosts first so the
            # gang spans as few hosts as possible (locality-first)
            order = np.argsort(self.free)[::-1]
            placement = []
            remaining = n
            for h in order:
                if self.free[h] == 0:
                    continue
                take = min(int(self.free[h]), remaining)
                placement.append((int(h), take))
                remaining -= take
                if remaining == 0:
                    break
        elif policy == "spread":
            # round-robin chips over hosts (load balancing)
            counts: Dict[int, int] = {}
            free = self.free.copy()
            remaining = n
            while remaining > 0:
                candidates = np.nonzero(free > 0)[0]
                if candidates.size == 0:
                    break
                h = int(candidates[np.argmax(free[candidates])])
                counts[h] = counts.get(h, 0) + 1
                free[h] -= 1
                remaining -= 1
            placement = sorted(counts.items())
        else:
            raise ValueError(policy)
        if remaining:
            return None
        for h, c in placement:
            self.free[h] -= c
            self.jobs_on_host[h].add(job_id)
        return Allocation(job_id, sorted(placement))

    # ---- fixed-slice baselines ----------------------------------------------
    def alloc_slices(self, job_id: str, n_chips: int,
                     slice_size: int) -> Optional[Allocation]:
        """Whole-slice allocation: ceil(n/slice) slices, each on one host.

        This emulates the paper's k-containers-per-VM baselines: a host
        holds ``chips_per_host // slice_size`` slices; slices are never
        shared between jobs.
        """
        n_slices = -(-n_chips // slice_size)
        placement: Dict[int, int] = {}
        need = n_slices
        for h in np.argsort(self.free)[::-1]:
            while self.free[h] - placement.get(int(h), 0) >= slice_size \
                    and need > 0:
                placement[int(h)] = placement.get(int(h), 0) + slice_size
                need -= 1
            if need == 0:
                break
        if need:
            return None
        for h, c in placement.items():
            self.free[h] -= c
            self.jobs_on_host[h].add(job_id)
        return Allocation(job_id, sorted(placement.items()),
                          slice_size=slice_size)

    # ---- free ----------------------------------------------------------------
    def release(self, alloc: Allocation) -> None:
        for h, c in alloc.placement:
            self.free[h] += c
            self.jobs_on_host[h].discard(alloc.job_id)
        assert (self.free <= self.chips_per_host).all()

    # ---- migration (defragmentation at barrier points) ------------------------
    def migration_plan(self, allocs: Sequence[Allocation]
                       ) -> List[Tuple[str, List[Tuple[int, int]]]]:
        """For each fragmented granular gang, try to consolidate onto fewer
        hosts using currently-free chips (+ the chips the gang already
        holds).  Returns [(job_id, new_placement)]."""
        plans = []
        free = self.free.copy()
        for alloc in allocs:
            if alloc.slice_size or alloc.fragmentation() <= 1:
                continue
            held = dict(alloc.placement)
            avail = free.copy()
            for h, c in held.items():
                avail[h] += c
            # can the gang fit on fewer hosts?
            order = np.argsort(avail)[::-1]
            new_placement, remaining = [], alloc.n
            for h in order:
                if avail[h] <= 0 or remaining == 0:
                    break
                take = min(int(avail[h]), remaining)
                new_placement.append((int(h), take))
                remaining -= take
            if remaining == 0 and len(new_placement) < alloc.fragmentation():
                plans.append((alloc.job_id, sorted(new_placement)))
                # commit against the scratch free map so plans don't overlap
                for h, c in held.items():
                    free[h] += c
                for h, c in new_placement:
                    free[h] -= c
        return plans

    def apply_migration(self, alloc: Allocation,
                        new_placement: List[Tuple[int, int]]) -> Allocation:
        self.release(alloc)
        for h, c in new_placement:
            self.free[h] -= c
            self.jobs_on_host[h].add(alloc.job_id)
        assert (self.free >= 0).all()
        return Allocation(alloc.job_id, sorted(new_placement))
