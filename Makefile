# Tier-1 verification and fast iteration targets.
PY ?= python

.PHONY: check quick bench-smoke

# the repo's tier-1 gate (see ROADMAP.md)
check:
	PYTHONPATH=src $(PY) -m pytest -x -q

# fast subset for scheduler/placement/simulator/fabric iteration
quick:
	PYTHONPATH=src $(PY) -m pytest -q -k "(placement or scheduler or simulator or fabric) and not run_trace and not gangs and not resume and not shared"

# benchmark smoke (the CI bench step)
bench-smoke:
	PYTHONPATH=src:. $(PY) benchmarks/run.py --only bench_makespan
