"""Paper Fig 11: cluster-size scaling — 50/100/200/400-job traces on
16/32/64/128 hosts; makespan + execution-time distribution + the
centralised-scheduler degradation at 128 hosts.
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator as S


def run(report):
    for hosts, njobs in ((16, 50), (32, 100), (64, 200), (128, 400)):
        jobs = S.generate_trace(njobs, "mpi-compute", seed=hosts)
        res = S.run_baselines(jobs, hosts=hosts)
        fa = res["faabric"]
        report(f"makespan/{hosts}h/faabric", round(fa.makespan, 1), "s",
               "Fig11a")
        best_base = min(v.makespan for k, v in res.items() if k != "faabric")
        worst_base = max(v.makespan for k, v in res.items()
                         if k != "faabric")
        report(f"makespan/{hosts}h/best_baseline", round(best_base, 1), "s",
               "Fig11a")
        report(f"makespan/{hosts}h/worst_baseline", round(worst_base, 1),
               "s", "Fig11a")
        et = np.array(fa.exec_times)
        report(f"exec/{hosts}h/p25", round(float(np.percentile(et, 25)), 1),
               "s", "Fig11b")
        report(f"exec/{hosts}h/p50", round(float(np.percentile(et, 50)), 1),
               "s", "Fig11b")
        report(f"exec/{hosts}h/p75", round(float(np.percentile(et, 75)), 1),
               "s", "Fig11b")
        report(f"sched_latency/{hosts}h",
               round(S.SCHED_LATENCY_PER_HOST * hosts * njobs, 1),
               "s total", "Fig11a centralised-scheduler cost")
