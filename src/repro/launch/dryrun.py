import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver builds the step function the shape dictates
(train_step / prefill_step / serve_step), assigns production shardings
(models.shardings), lowers and compiles it against ShapeDtypeStruct inputs
on the production mesh (single-pod 16x16 = 256 chips, multi-pod 2x16x16 =
512 chips), and extracts:

  * memory_analysis()   -> per-device bytes (proves the cell fits HBM)
  * cost_analysis()     -> per-device HLO FLOPs + bytes accessed
  * compiled.as_text()  -> per-collective byte counts (roofline's third term)

Results go to ``results/dryrun/<cell>.json``; ``--all`` fans cells out to
subprocesses (one compile per process keeps XLA state isolated).

NOTE: the XLA_FLAGS line above must run before ANY jax import — jax locks
the device count at first init.  Do not move it.
"""
import argparse
import json
import subprocess
import sys
import time
from typing import Any, Dict, Optional

import jax

from repro.configs.base import SHAPES, ArchConfig, ShapeConfig, cell_applicable
from repro.configs.registry import ARCH_IDS, get_config
from repro.launch import hloanalysis
from repro.launch.mesh import make_production_mesh
from repro.models import model as model_mod
from repro.models import shardings as sh
from repro.optim.adamw import AdamWConfig

# TPU v5e per-chip constants (roofline denominators)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link (slow-link bound for collectives)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")

# Gradient accumulation per arch for the train_4k shape: keeps per-device
# activation checkpoints within v5e HBM (napkin math in EXPERIMENTS.md).
GRAD_ACCUM = {
    "phi3.5-moe-42b-a6.6b": 4, "glm4-9b": 4, "llama-3.2-vision-11b": 4,
    "minitron-4b": 2, "llama3.2-3b": 2, "zamba2-2.7b": 4, "xlstm-1.3b": 4,
    "llama3.2-1b": 2, "granite-moe-1b-a400m": 2, "whisper-small": 2,
}


def dryrun_config(arch: str, deploy: bool = False) -> ArchConfig:
    """Dry-run overrides.

    analysis build (deploy=False): unrolled layers + python inner loops —
    the HLO contains every FLOP and collective exactly once per execution.
    deploy build (deploy=True): lax.scan layers + inner loops — the
    deployable artifact whose buffer reuse gives the real memory footprint.
    FSDP turns on when TP-only optimizer state would exceed ~2 GB/chip.
    """
    cfg = get_config(arch)
    # FSDP only when TP-only optimizer state exceeds ~2 GB/chip: blanket
    # FSDP regressed memory badly (XLA hoists loop-invariant all-gathers
    # out of the layer scan, materialising the whole gathered model).
    big = model_mod.count_params(cfg) * 16 / 256 > 2e9
    return cfg.with_(scan_layers=deploy, remat=True, fsdp=big,
                     deploy=deploy)


def build_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    """Returns (fn, args, in_shardings) for this cell."""
    ocfg = AdamWConfig()
    if shape.kind == "train":
        # grad accumulation exists purely to bound activation memory: the
        # deploy build uses it; the analysis build lowers the full batch in
        # one pass (identical total FLOPs, 4x smaller unrolled HLO)
        accum = (GRAD_ACCUM.get(cfg.name, 1)
                 if (shape.name == "train_4k" and cfg.deploy) else 1)
        gspecs = sh.param_pspecs(cfg, model_mod.param_specs(cfg), mesh)
        state = model_mod.train_state_specs(cfg, ocfg)
        batch = model_mod.batch_specs(cfg, shape)
        fn = model_mod.make_train_step(
            cfg, ocfg, grad_accum=accum, grad_pspecs=gspecs,
            batch_pspecs=sh.batch_pspecs(cfg, batch, mesh))
        in_sh = (sh.named(mesh, sh.state_pspecs(cfg, state, mesh)),
                 sh.named(mesh, sh.batch_pspecs(cfg, batch, mesh)))
        return fn, (state, batch), in_sh
    if shape.kind == "prefill":
        fn = model_mod.make_prefill_step(cfg)
        params = model_mod.param_specs(cfg)
        batch = model_mod.batch_specs(cfg, shape, with_labels=False)
        in_sh = (sh.named(mesh, sh.param_pspecs(cfg, params, mesh)),
                 sh.named(mesh, sh.batch_pspecs(cfg, batch, mesh)))
        return fn, (params, batch), in_sh
    # decode
    window = model_mod.decode_window(cfg, shape)
    fn = model_mod.make_serve_step(cfg, window=window)
    params = model_mod.param_specs(cfg)
    states = model_mod.decode_state_specs(cfg, shape)
    inputs = model_mod.decode_input_specs(cfg, shape)
    in_sh = (sh.named(mesh, sh.param_pspecs(cfg, params, mesh)),
             sh.named(mesh, sh.decode_state_pspecs(cfg, states, mesh)),
             sh.named(mesh, sh.batch_pspecs(cfg, inputs, mesh))["tokens"],
             sh.named(mesh, sh.batch_pspecs(cfg, inputs, mesh))["positions"])
    return fn, (params, states, inputs["tokens"], inputs["positions"]), in_sh


def roofline(cost: Dict[str, float], coll: Dict[str, int],
             cfg: ArchConfig, shape: ShapeConfig, n_chips: int
             ) -> Dict[str, Any]:
    """Three-term roofline from the per-device compiled module."""
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(coll.get("hbm_bytes", cost.get("bytes accessed", 0.0)))
    coll_dev = float(coll.get("collective_bytes", 0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    n_active = model_mod.count_params(cfg, active_only=True)
    passes = 6 if shape.kind == "train" else 2
    model_flops = passes * n_active * tokens
    hlo_total = flops_dev * n_chips
    return {
        "per_device": {"flops": flops_dev, "hbm_bytes": bytes_dev,
                       "collective_bytes": coll_dev},
        "terms_s": terms,
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_flops_ratio": (model_flops / hlo_total) if hlo_total else 0,
        "roofline_fraction": (model_flops / n_chips / PEAK_FLOPS)
        / max(max(terms.values()), 1e-12),
        "step_time_bound_s": max(terms.values()),
    }


def _compile(cfg, shape, mesh):
    fn, args, in_sh = build_cell(cfg, shape, mesh)
    t0 = time.time()
    with mesh:
        lowered = jax.jit(fn, in_shardings=in_sh).lower(*args)
        lower_s = round(time.time() - t0, 1)
        t0 = time.time()
        compiled = lowered.compile()
    return compiled, lower_s, round(time.time() - t0, 1)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             out_path: Optional[str] = None) -> Dict[str, Any]:
    shape = SHAPES[shape_name]
    ok, reason = cell_applicable(get_config(arch), shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec: Dict[str, Any] = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "applicable": ok}
    if not ok:
        rec["skip_reason"] = reason
        return _emit(rec, out_path)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size

    # --- deploy build: the runnable artifact; memory truth ---
    cfg_d = dryrun_config(arch, deploy=True)
    compiled_d, rec["deploy_lower_s"], rec["deploy_compile_s"] = _compile(
        cfg_d, shape, mesh)
    mem = compiled_d.memory_analysis()
    rec["memory"] = {
        "argument_bytes": int(mem.argument_size_in_bytes),
        "output_bytes": int(mem.output_size_in_bytes),
        "temp_bytes": int(mem.temp_size_in_bytes),
        "alias_bytes": int(mem.alias_size_in_bytes),
        "peak_per_device_gb": round(
            (mem.argument_size_in_bytes + mem.temp_size_in_bytes
             + max(0, mem.output_size_in_bytes
                   - mem.alias_size_in_bytes)) / 2 ** 30, 3),
    }
    rec["fits_hbm_16gb"] = rec["memory"]["peak_per_device_gb"] < 16.0
    del compiled_d
    if multi_pod:
        # multi-pod pass proves the "pod" axis shards (deploy compile +
        # memory); the roofline table is single-pod only (instructions).
        return _emit(rec, out_path)

    # --- analysis builds: unrolled; FLOP/collective truth ---
    # Difference method (single-core budget): compile 1-period and 2-period
    # unrolled models; per-period cost is exact for homogeneous periods, so
    #   total = cost(1p) + (n_periods - 1) * (cost(2p) - cost(1p)).
    # Embedding/loss/optimizer-fixed parts live in cost(1p) and cancel in
    # the delta.  Documented in EXPERIMENTS.md §Roofline.
    cfg_a = dryrun_config(arch, deploy=False)
    period_len = len(cfg_a.period())
    n_per = cfg_a.n_periods()
    measures = []
    for k in (1, 2):
        cfg_k = cfg_a.with_(n_layers=period_len * k)
        compiled_k, lo_s, co_s = _compile(cfg_k, shape, mesh)
        cost = compiled_k.cost_analysis()
        hlo = compiled_k.as_text()
        ana = hloanalysis.analyze(hlo)
        measures.append({
            "flops": float(cost.get("flops", 0.0)),
            "hbm_bytes": float(ana["hbm_bytes"]),
            "collectives": ana,
            "lower_s": lo_s, "compile_s": co_s, "hlo_bytes": len(hlo)})
        del compiled_k
    m1, m2 = measures
    extrap = lambda a, b: a + (n_per - 1) * (b - a)
    cost_full = {"flops": extrap(m1["flops"], m2["flops"])}
    ana_full = {
        k: max(0, int(extrap(m1["collectives"][k], m2["collectives"][k])))
        for k in m2["collectives"]}
    rec["analysis"] = {"one_period": m1, "two_periods": m2,
                       "n_periods": n_per, "period_len": period_len}
    rec["collectives"] = ana_full
    rec["cost"] = cost_full
    rec["compile_s"] = m1["compile_s"] + m2["compile_s"]
    rec["roofline"] = roofline(cost_full, ana_full, cfg_a, shape, n_chips)
    return _emit(rec, out_path)


def _emit(rec, out_path):
    if out_path:
        os.makedirs(os.path.dirname(out_path), exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run every (arch x shape x mesh) cell in "
                         "subprocesses")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--out-dir", default=os.path.abspath(RESULTS_DIR))
    args = ap.parse_args()

    if not args.all:
        assert args.arch and args.shape
        out = os.path.join(
            args.out_dir, f"{args.arch}__{args.shape}__"
            f"{'2x16x16' if args.multi_pod else '16x16'}.json")
        rec = run_cell(args.arch, args.shape, args.multi_pod, out)
        print(json.dumps(rec, indent=1))
        return

    # fan out cells to subprocesses (isolated XLA state, bounded RAM)
    cells = []
    for mp in (False, True):   # single-pod first: the roofline table
        for arch in ARCH_IDS:
            for shape_name in SHAPES:
                cells.append((arch, shape_name, mp))
    procs: Dict[Any, Any] = {}
    failures = []
    while cells or procs:
        while cells and len(procs) < args.jobs:
            arch, shape_name, mp = cells.pop(0)
            out = os.path.join(
                args.out_dir, f"{arch}__{shape_name}__"
                f"{'2x16x16' if mp else '16x16'}.json")
            if os.path.exists(out):
                print(f"skip (cached): {out}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape_name,
                   "--out-dir", args.out_dir]
            if mp:
                cmd.append("--multi-pod")
            procs[subprocess.Popen(
                cmd, stdout=subprocess.DEVNULL,
                stderr=subprocess.PIPE)] = (arch, shape_name, mp)
        done = [p for p in procs if p.poll() is not None]
        for p in done:
            cell = procs.pop(p)
            if p.returncode != 0:
                err = p.stderr.read().decode()[-2000:]
                failures.append((cell, err))
                print(f"FAIL {cell}:\n{err}")
            else:
                print(f"ok   {cell}")
        time.sleep(2)
    print(f"\n{len(failures)} failures")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
