"""Substrate tests: data pipeline determinism, optimizer, compression,
snapshots, checkpoint manager (full + incremental), granule groups."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.core import snapshot as snap_mod
from repro.core.granule import GranuleGroup
from repro.data import pipeline as dp
from repro.optim import adamw, compress


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------
def test_batches_deterministic_in_step():
    cfg = dp.DataConfig(seed=3, vocab=1000, seq_len=64, global_batch=8)
    b1 = dp.make_batch(cfg, 7)
    b2 = dp.make_batch(cfg, 7)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = dp.make_batch(cfg, 8)
    assert not np.array_equal(b1["tokens"], b3["tokens"])


def test_shard_slices_partition_batch():
    cfg = dp.DataConfig(vocab=100, seq_len=16, global_batch=8)
    b = dp.make_batch(cfg, 0)
    slices = [dp.shard_slice(b, r, 4) for r in range(4)]
    recon = np.concatenate([np.asarray(s["tokens"]) for s in slices])
    np.testing.assert_array_equal(recon, np.asarray(b["tokens"]))
    # re-partitioning at a different world size covers the same data
    slices2 = [dp.shard_slice(b, r, 2) for r in range(2)]
    recon2 = np.concatenate([np.asarray(s["tokens"]) for s in slices2])
    np.testing.assert_array_equal(recon2, np.asarray(b["tokens"]))


def test_labels_are_shifted_tokens():
    cfg = dp.DataConfig(vocab=100, seq_len=16, global_batch=2)
    b = dp.make_batch(cfg, 0)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------
def test_adamw_minimises_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=1,
                            total_steps=200)
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = adamw.apply(g, state, params, cfg)
    assert float(loss(params)) < 1e-2


def test_clip_by_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = adamw.clip_by_global_norm(g, 1.0)
    assert abs(float(adamw.global_norm(clipped)) - 1.0) < 1e-5
    assert float(norm) > 1.0


def test_cosine_schedule_monotone_after_warmup():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    lrs = [float(adamw.cosine_lr(cfg, jnp.asarray(s))) for s in range(100)]
    assert lrs[0] < lrs[9]                       # warmup rises
    assert all(a >= b - 1e-9 for a, b in zip(lrs[10:], lrs[11:]))
    assert lrs[-1] >= cfg.lr * cfg.min_lr_frac * 0.99


# ---------------------------------------------------------------------------
# compression (top-k delta + error feedback)
# ---------------------------------------------------------------------------
def test_compress_roundtrip_preserves_total_signal():
    key = jax.random.PRNGKey(0)
    grads = {"w": jax.random.normal(key, (1000,))}
    resid = compress.init_residual(grads)
    sparse, new_resid = compress.compress(grads, resid, frac=0.1)
    dense = compress.decompress(sparse, grads)
    # compressed + residual == original (nothing lost, only deferred)
    np.testing.assert_allclose(
        np.asarray(dense["w"] + new_resid["w"]),
        np.asarray(grads["w"]), atol=1e-6)
    assert compress.compression_ratio(sparse, grads) < 0.25


def test_error_feedback_accumulates():
    grads = {"w": jnp.ones((100,))}
    resid = compress.init_residual(grads)
    sent_total = jnp.zeros((100,))
    for _ in range(10):
        sparse, resid = compress.compress(grads, resid, frac=0.05)
        sent_total = sent_total + compress.decompress(sparse, grads)["w"]
    # after k steps everything eventually ships (EF keeps the residual)
    assert float(jnp.abs(sent_total + resid["w"]
                         - 10 * grads["w"]).max()) < 1e-4


# ---------------------------------------------------------------------------
# snapshots
# ---------------------------------------------------------------------------
def test_snapshot_restore_bit_exact():
    state = {"w": jnp.arange(100, dtype=jnp.float32),
             "s": {"m": jnp.ones((3, 3))}}
    snap = snap_mod.take("j", 5, state)
    restored = snap_mod.restore(snap)
    assert snap_mod.verify(snap, snap_mod.take("j", 5, restored))


def test_snapshot_delta_chain():
    state = {"w": jnp.zeros(5000)}
    snap = snap_mod.take("j", 0, state)
    s1 = {"w": state["w"].at[17].set(1.0)}
    d = snap_mod.delta(snap, s1)
    snap1 = snap_mod.apply_delta(snap, d, 1)
    np.testing.assert_array_equal(snap1.state["w"], np.asarray(s1["w"]))
    assert snap1.fingerprint != snap.fingerprint


# ---------------------------------------------------------------------------
# checkpoint manager
# ---------------------------------------------------------------------------
def test_checkpoint_full_and_incremental(tmp_path):
    mgr = CheckpointManager(str(tmp_path), job_id="t", keep=10,
                            incremental_every=3)
    state = {"w": jnp.zeros(40000), "step": jnp.zeros(())}
    for step in range(5):
        state = {"w": state["w"].at[step].set(step + 1.0),
                 "step": jnp.asarray(float(step))}
        mgr.save(step, state, blocking=True)
    kinds = [s["incremental"] for s in mgr.stats]
    assert kinds == [False, True, True, False, True]
    restored, step = mgr.restore()
    assert step == 4
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.asarray(state["w"]))
    # incremental checkpoints are much smaller than full ones
    sizes = {s["step"]: s["bytes"] for s in mgr.stats}
    assert sizes[1] < sizes[0] / 2


def test_checkpoint_restore_specific_step(tmp_path):
    mgr = CheckpointManager(str(tmp_path), job_id="t2", keep=10)
    for step in range(3):
        mgr.save(step, {"w": jnp.full((10,), float(step))}, blocking=True)
    restored, step = mgr.restore(step=1)
    assert step == 1
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.full((10,), 1.0))


# ---------------------------------------------------------------------------
# granule groups
# ---------------------------------------------------------------------------
def test_granule_group_addressing_and_leaders():
    g = GranuleGroup("j", 8, [(i // 4, None) for i in range(8)])
    assert g.address_table() == {i: i // 4 for i in range(8)}
    assert g.leader_of(0) == 0 and g.leader_of(1) == 4
    assert g.fragmentation() == 2


def test_granule_messaging_survives_migration():
    g = GranuleGroup("j", 4, [(i // 2, None) for i in range(4)])
    g.send(0, 3, {"tag": "hello"})
    with pytest.raises(RuntimeError):
        g.migrate(3, 0)                       # in-flight message blocks it
    assert g.recv(3, 0) == {"tag": "hello"}
    g.migrate(3, 0)
    assert g.address_table()[3] == 0
    g.send(1, 3, "post-migration")            # rank addressing still works
    assert g.recv(3, 1) == "post-migration"


def test_vm_leader_schedule_fewer_cross_messages():
    g = GranuleGroup("j", 16, [(i // 8, None) for i in range(16)])
    sched = g.allreduce_message_schedule()
    assert sched["cross"] < sched["flat_cross"]
