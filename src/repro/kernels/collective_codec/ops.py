"""jit'd wrapper: threshold-select a flat gradient shard into a
fixed-size sparse (vals, idx) message plus error-feedback residual.

Pads the shard into ``(k, m)`` chunk rows (``k = max(1, int(n·frac))``
selected elements — the same message size as the old global top-k) and
runs the fused chunk-select kernel; large shards route through the
Pallas kernel, small ones use the bit-identical jnp reference (the
same large-leaf routing ``kernels/diff_merge`` uses in ``diffsync``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.collective_codec import kernel as _k
from repro.kernels.collective_codec.ref import chunk_select_ref

#: below this flat size the pallas_call launch costs more than it saves
#: (TPU routing threshold; non-TPU backends always use the jnp ref)
KERNEL_MIN_SIZE = 1 << 16


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def codec_geometry(n: int, frac: float):
    """(k, m, padded) chunk geometry for an ``n``-element shard:
    ``k`` selected elements (chunk rows), chunk width ``m = ceil(n/k)``.
    ``frac = 1.0`` degenerates to ``m = 1`` — every element selected,
    which makes the compressed collective bit-exact to hierarchical."""
    n = int(n)
    k = max(1, min(n, int(n * frac)))
    m = -(-n // k)
    return k, m, k * m


@functools.partial(jax.jit,
                   static_argnames=("frac", "use_kernel", "interpret"))
def select_codec(vec, *, frac: float,
                 use_kernel: bool | None = None,
                 interpret: bool | None = None):
    """vec: flat (n,) -> (vals (k,), idx (k,) int32, resid (n,)).

    ``vals[i] = vec[idx[i]]`` is the largest-magnitude element of chunk
    ``i``; ``resid`` is ``vec`` with the selected elements zeroed, so
    ``scatter(vals, idx) + resid == vec`` exactly (error feedback)."""
    n = vec.shape[0]
    k, m, padded = codec_geometry(n, frac)
    if interpret is None:
        interpret = _interpret_default()
    if use_kernel is None:
        # same routing as core.diffsync: the kernel is a TPU fast path;
        # CPU hosts stay on the vectorized jnp ref (running the kernel
        # interpreted per grid row would be orders of magnitude slower)
        use_kernel = (n >= KERNEL_MIN_SIZE
                      and jax.default_backend() == "tpu")
    x = vec
    if padded != n:
        x = jnp.pad(x, (0, padded - n))
    x = x.reshape(k, m)
    if use_kernel:
        rows = _k.BLOCK_ROWS if k % _k.BLOCK_ROWS == 0 else 1
        vals, col, resid = _k.chunk_select(x, block_rows=rows,
                                           interpret=interpret)
    else:
        vals, col, resid = chunk_select_ref(x)
    idx = jnp.arange(k, dtype=jnp.int32) * m + col[:, 0]
    # padding lanes are zero, so a padded-chunk pick is (0.0, idx < n)
    # clamped into range: scatter-adding 0.0 is a no-op either way
    idx = jnp.minimum(idx, n - 1)
    return vals[:, 0], idx, resid.reshape(-1)[:n]
