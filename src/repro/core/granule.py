"""Granules and Granule groups (paper §3.1, §5.1).

A Granule is the schedulable unit: in Faabric it is one thread/process of a
parallel application; in this TPU adaptation it is **one device's shard of
an SPMD job step**.  A job requesting parallelism *n* is a *gang* of *n*
Granules organised in a ``GranuleGroup`` — the analogue of an MPI
communicator: every Granule has a stable *index* (rank), and the group keeps
an **address table** mapping index -> (host, device) that survives
migration, exactly like the paper's per-VM group metadata replicas.

Message queues: each Granule owns a set of in-memory queues keyed by sender
index.  Queues buffer control-plane messages (migration notices, barrier
tokens, diff payloads) so delivery is independent of Granule placement —
data-plane traffic goes through XLA collectives on the group's mesh.
"""
from __future__ import annotations

import collections
import dataclasses
import threading
from typing import Any, Dict, List, Sequence, Tuple


@dataclasses.dataclass
class Granule:
    """One schedulable shard of a job."""
    job_id: str
    index: int                      # rank within the GranuleGroup
    host: int                       # host (VM/pod) id
    device: Any = None              # jax device backing this Granule
    semantics: str = "process"      # "thread" (shared memory) | "process"
    state: str = "running"          # running | barrier | migrating | done


class GranuleGroup:
    """Rank-indexed gang with an address table and per-rank queues."""

    def __init__(self, job_id: str, size: int,
                 placement: Sequence[Tuple[int, Any]],
                 semantics: str = "process"):
        assert len(placement) == size
        self.job_id = job_id
        self.size = size
        self.granules = [
            Granule(job_id=job_id, index=i, host=h, device=d,
                    semantics=semantics)
            for i, (h, d) in enumerate(placement)]
        # per-rank FIFO queues: queues[dst][src] -> deque of messages
        self._queues: List[Dict[int, collections.deque]] = [
            collections.defaultdict(collections.deque) for _ in range(size)]
        self._lock = threading.Lock()
        self.epoch = 0              # bumped on every migration

    # ---- address table ----------------------------------------------------
    def address_table(self) -> Dict[int, int]:
        """rank -> host id (the paper's group metadata replica)."""
        return {g.index: g.host for g in self.granules}

    def hosts(self) -> List[int]:
        return sorted({g.host for g in self.granules})

    def ranks_on_host(self, host: int) -> List[int]:
        return [g.index for g in self.granules if g.host == host]

    def leader_of(self, host: int) -> int:
        """VM-leader (paper §5.3): lowest rank on the host."""
        ranks = self.ranks_on_host(host)
        if not ranks:
            raise KeyError(f"no granules on host {host}")
        return min(ranks)

    def devices(self) -> List[Any]:
        return [g.device for g in self.granules]

    def fragmentation(self) -> int:
        """Number of hosts the gang spans (1 = fully co-located)."""
        return len(self.hosts())

    # ---- messaging (control plane) -----------------------------------------
    def send(self, src: int, dst: int, msg: Any) -> None:
        """Asynchronous point-to-point send; never blocks (paper §5.1)."""
        with self._lock:
            self._queues[dst][src].append(msg)

    def recv(self, dst: int, src: int) -> Any:
        with self._lock:
            q = self._queues[dst][src]
            if not q:
                raise LookupError(f"no message from {src} to {dst}")
            return q.popleft()

    def pending(self, dst: int) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues[dst].values())

    def in_flight(self) -> int:
        """Total queued messages — must be 0 at a barrier control point
        before migration is allowed (paper §5.2)."""
        with self._lock:
            return sum(len(q) for qs in self._queues for q in qs.values())

    # ---- migration --------------------------------------------------------
    def migrate(self, index: int, new_host: int, new_device: Any = None
                ) -> None:
        """Re-address one Granule; queues are keyed by rank so buffered
        messages survive the move (paper Fig 8)."""
        if self.in_flight():
            raise RuntimeError(
                "migration requires an empty message plane (barrier point)")
        g = self.granules[index]
        g.host = new_host
        if new_device is not None:
            g.device = new_device
        self.epoch += 1

    def readdress(self, placement: Sequence[Tuple[int, Any]]) -> None:
        """Gang-wide ``migrate``: re-address every rank in place at a
        barrier point.  Rank-keyed queues and granule identity survive —
        only (host, device) change — and the whole move is one migration
        epoch (paper Fig 8's group-metadata update)."""
        assert len(placement) == self.size, "readdress keeps the gang size"
        if self.in_flight():
            raise RuntimeError(
                "migration requires an empty message plane (barrier point)")
        changed = False
        for g, (h, d) in zip(self.granules, placement):
            if g.host != h or g.device is not d:
                g.host, g.device = h, d
                changed = True
        if changed:
            self.epoch += 1

    def resize(self, placement: Sequence[Tuple[int, Any]]) -> None:
        """Elastic grow/shrink in place at a barrier point: surviving
        ranks keep their queues and identity, new ranks start empty,
        dropped ranks disappear (their queues are empty — the barrier
        guarantees no in-flight messages)."""
        if self.in_flight():
            raise RuntimeError(
                "resize requires an empty message plane (barrier point)")
        new_size = len(placement)
        semantics = self.granules[0].semantics if self.granules else "process"
        granules: List[Granule] = []
        for i, (h, d) in enumerate(placement):
            if i < self.size:
                g = self.granules[i]
                g.host, g.device = h, d
            else:
                g = Granule(job_id=self.job_id, index=i, host=h, device=d,
                            semantics=semantics)
            granules.append(g)
        self.granules = granules
        self._queues = (self._queues[:new_size]
                        + [collections.defaultdict(collections.deque)
                           for _ in range(max(0, new_size - self.size))])
        self.size = new_size
        self.epoch += 1

    # ---- collective message schedule (paper Fig 9) -------------------------
    def allreduce_message_schedule(self) -> Dict[str, int]:
        """Count intra-host vs cross-host messages for a VM-leader two-level
        all-reduce vs a flat one (used by benchmarks and the simulator)."""
        hosts = self.hosts()
        leaders = {h: self.leader_of(h) for h in hosts}
        main_host = self.granules[0].host
        intra = cross = 0
        # reduce: every granule -> its leader (intra), leaders -> main leader
        for g in self.granules:
            if g.index == leaders[g.host]:
                continue
            intra += 1
        cross += sum(1 for h in hosts if h != main_host)
        # broadcast: reverse of the same schedule
        cross += sum(1 for h in hosts if h != main_host)
        intra += sum(1 for g in self.granules
                     if g.index != leaders[g.host])
        flat_cross = 2 * sum(1 for g in self.granules
                             if g.host != main_host)
        return {"intra": intra, "cross": cross, "flat_cross": flat_cross}


def make_group_from_devices(job_id: str, devices: Sequence[Any],
                            chips_per_host: int,
                            semantics: str = "process") -> GranuleGroup:
    """Build a GranuleGroup from concrete jax devices; host id is derived
    from the device id so co-location structure is preserved on the
    CPU-host test fabric."""
    placement = [(d.id // chips_per_host, d) for d in devices]
    return GranuleGroup(job_id, len(devices), placement,
                        semantics=semantics)
