"""Property-based tests (hypothesis, with example fallback) for the
byte-wise diff protocol — Table 3 merge-op algebra and diff/apply
invariants (paper §4)."""
import jax
import numpy as np

import _hyp_compat as hc
from repro.core import diffsync as D


def _arr(n: int, seed: int) -> np.ndarray:
    return np.random.default_rng(seed).normal(
        size=n).astype(np.float32) + 2.0


def _arrays(st):
    return st.integers(1, 4000).flatmap(
        lambda n: st.builds(
            lambda seed: np.random.default_rng(seed).normal(
                size=n).astype(np.float32) + 2.0,
            st.integers(0, 2 ** 16)))


_EXAMPLE_ARRAYS = [_arr(1, 0), _arr(7, 1), _arr(400, 2), _arr(4000, 3)]


@hc.hyp_or_examples(
    lambda st: (_arrays(st), st.integers(0, 2 ** 16)),
    examples=[(a, s) for s, a in enumerate(_EXAMPLE_ARRAYS)])
def test_sum_merge_is_grad_accumulation(a0, seed):
    """A1 = A0 + (B1 - B0): merging N children == summing their deltas."""
    rng = np.random.default_rng(seed)
    b0 = a0.copy()
    deltas = [np.zeros_like(a0) for _ in range(3)]
    for d in deltas:
        idx = rng.integers(0, a0.size, size=max(1, a0.size // 7))
        d[idx] = rng.normal(size=idx.size).astype(np.float32)
    main = a0.copy()
    for d in deltas:
        main = D.apply_leaf(main, D.diff_leaf(b0, b0 + d, op="sum"))
    np.testing.assert_allclose(main, a0 + sum(deltas), atol=1e-5)


@hc.hyp_or_examples(lambda st: (_arrays(st),), examples=_EXAMPLE_ARRAYS)
def test_overwrite_roundtrip(a0):
    """diff(old, new) applied to old reproduces new exactly."""
    rng = np.random.default_rng(1)
    new = a0.copy()
    idx = rng.integers(0, a0.size, size=max(1, a0.size // 5))
    new[idx] += 1.0
    d = D.diff_leaf(a0, new, op="overwrite")
    np.testing.assert_array_equal(D.apply_leaf(a0, d), new)


@hc.hyp_or_examples(lambda st: (_arrays(st),), examples=_EXAMPLE_ARRAYS)
def test_clean_state_empty_diff(a0):
    d = D.diff_leaf(a0, a0.copy())
    assert d.idx.size == 0
    np.testing.assert_array_equal(D.apply_leaf(a0, d), a0)


@hc.hyp_or_examples(
    lambda st: (_arrays(st), st.sampled_from(["sum", "subtract"])),
    examples=[(_EXAMPLE_ARRAYS[1], "sum"), (_EXAMPLE_ARRAYS[2], "subtract"),
              (_EXAMPLE_ARRAYS[3], "sum")])
def test_sum_subtract_inverse(a0, op):
    """subtract(A0, B0, B1) == sum(A0, B1, B0): Table 3 algebra."""
    rng = np.random.default_rng(2)
    b0 = a0.copy()
    b1 = b0 + rng.normal(size=a0.shape).astype(np.float32)
    via_sub = D.apply_leaf(a0, D.diff_leaf(b0, b1, op="subtract"))
    via_sum = D.apply_leaf(a0, D.diff_leaf(b1, b0, op="sum"))
    np.testing.assert_allclose(via_sub + via_sum, 2 * a0, atol=1e-4)


@hc.hyp_or_examples(lambda st: (st.integers(0, 2 ** 16),),
                    examples=[0, 7, 12345], max_examples=30)
def test_multiply_merge(seed):
    rng = np.random.default_rng(seed)
    a0 = rng.uniform(1, 2, 2048).astype(np.float32)
    b0 = rng.uniform(1, 2, 2048).astype(np.float32)
    scale = rng.uniform(0.5, 2.0)
    b1 = (b0 * scale).astype(np.float32)
    merged = D.apply_leaf(a0, D.diff_leaf(b0, b1, op="multiply"))
    np.testing.assert_allclose(merged, a0 * scale, rtol=1e-4)


@hc.hyp_or_examples(lambda st: (st.integers(0, 2 ** 16),),
                    examples=[1, 42, 65535], max_examples=20)
def test_tree_diff_only_ships_dirty_bytes(seed):
    rng = np.random.default_rng(seed)
    tree = {"a": rng.normal(size=(64, 64)).astype(np.float32),
            "b": rng.normal(size=(10,)).astype(np.float32)}
    new = {"a": tree["a"].copy(), "b": tree["b"].copy()}
    new["a"][0, 0] += 1.0
    diffs = D.diff_tree(tree, new)
    assert len(diffs) == 1                    # only leaf 'a' is dirty
    assert D.diff_nbytes(diffs) < tree["a"].nbytes + tree["b"].nbytes
    merged = D.apply_tree(tree, diffs)
    np.testing.assert_array_equal(merged["a"], new["a"])
    np.testing.assert_array_equal(merged["b"], tree["b"])


def test_dense_diff_matches_sparse():
    rng = np.random.default_rng(0)
    old = rng.normal(size=5000).astype(np.float32)
    new = old.copy()
    new[100:200] += 1.5
    import jax.numpy as jnp
    mask, delta = jax.jit(D.dense_diff)(jnp.asarray(old), jnp.asarray(new))
    sparse = D.diff_leaf(old, new, op="sum")
    np.testing.assert_array_equal(np.nonzero(np.asarray(mask))[0],
                                  sparse.idx)
    merged = jax.jit(lambda m, ms, p: D.dense_merge(m, ms, p, op="sum"))(
        jnp.asarray(old), mask, delta)
    np.testing.assert_allclose(np.asarray(merged), new, atol=1e-6)


# ---------------------------------------------------------------------------
# Parity suite: the vectorized hot path is pinned bit-exact against the
# pre-vectorization reference implementations (ISSUE 6 tentpole)
# ---------------------------------------------------------------------------
_PARITY_SIZES = (1, 7, 1023, 1024, 1025, 4000, 65536)


def _dirty_pair(n, dtype, seed, frac=9):
    rng = np.random.default_rng(seed)
    b0 = (rng.normal(size=n) + 2.0).astype(dtype)
    b1 = b0.copy()
    idx = rng.integers(0, n, size=max(1, n // frac))
    b1[idx] = (rng.normal(size=idx.size) + 3.0).astype(dtype)
    return b0, b1


@hc.hyp_or_examples(
    lambda st: (st.sampled_from(list(D.MERGE_OPS)),
                st.sampled_from(list(_PARITY_SIZES)),
                st.integers(0, 2 ** 16)),
    examples=[(op, n, i) for i, (op, n) in enumerate(
        (op, n) for op in D.MERGE_OPS for n in (7, 1024, 4000))])
def test_parity_with_reference_float(op, n, seed):
    """diff_leaf/apply_leaf match reference_* bit-for-bit on floats."""
    rng = np.random.default_rng(seed)
    a0 = (rng.normal(size=n) + 2.0).astype(np.float32)
    b0, b1 = _dirty_pair(n, np.float32, seed + 1)
    d_new = D.diff_leaf(b0, b1, op=op)
    d_ref = D.reference_diff_leaf(b0, b1, op=op)
    np.testing.assert_array_equal(d_new.idx, d_ref.idx)
    np.testing.assert_array_equal(d_new.new, d_ref.new)
    np.testing.assert_array_equal(d_new.old, d_ref.old)
    np.testing.assert_array_equal(D.apply_leaf(a0, d_new),
                                  D.reference_apply_leaf(a0, d_ref))


def test_parity_with_reference_tree():
    rng = np.random.default_rng(3)
    tree = {"w": rng.normal(size=(80, 33)).astype(np.float32),
            "b": rng.normal(size=(130,)).astype(np.float64),
            "clean": rng.normal(size=(50,)).astype(np.float32)}
    new = {k: v.copy() for k, v in tree.items()}
    new["w"][5, :] += 1.0
    new["b"][100:] *= 1.5
    diffs = D.diff_tree(tree, new, op="overwrite")
    got = D.apply_tree(tree, diffs)
    ref = D.reference_apply_tree(tree, diffs)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(ref[k]))
    # untouched leaves pass through as the same object (no copy)
    assert got["clean"] is tree["clean"]


# ---------------------------------------------------------------------------
# Round-trips across dtypes / ragged shapes / all five ops (satellite 3)
# ---------------------------------------------------------------------------
def _dtypes():
    import ml_dtypes
    return [np.float32, np.float64, np.int32, ml_dtypes.bfloat16]


@hc.hyp_or_examples(
    lambda st: (st.sampled_from(_dtypes()),
                st.sampled_from([1, 13, 1023, 1025, 5000]),
                st.integers(0, 2 ** 16)),
    examples=[(dt, n, i) for i, (dt, n) in enumerate(
        (dt, n) for dt in _dtypes() for n in (13, 1025, 5000))])
def test_overwrite_roundtrip_dtypes_ragged(dtype, n, seed):
    """diff -> apply reproduces the child exactly for every dtype,
    including ragged non-multiple-of-CHUNK shapes."""
    rng = np.random.default_rng(seed)
    if np.issubdtype(np.dtype(dtype), np.integer):
        old = rng.integers(-1000, 1000, size=n).astype(dtype)
        new = old.copy()
        new[rng.integers(0, n, size=max(1, n // 5))] += 7
    else:
        old = (rng.normal(size=n) + 2.0).astype(dtype)
        new = old.copy()
        idx = rng.integers(0, n, size=max(1, n // 5))
        new[idx] = (rng.normal(size=idx.size) + 3.0).astype(dtype)
    d = D.diff_leaf(old, new, op="overwrite")
    got = D.apply_leaf(old, d)
    assert got.dtype == old.dtype
    np.testing.assert_array_equal(got, new)


@hc.hyp_or_examples(
    lambda st: (st.sampled_from(list(D.MERGE_OPS)),
                st.integers(0, 2 ** 16)),
    examples=[(op, i) for i, op in enumerate(D.MERGE_OPS)])
def test_all_ops_roundtrip_ragged(op, seed):
    """Five-op merge algebra on a ragged leaf: merged value matches the
    scalarwise oracle applied to the dirty chunks."""
    n = 3333
    rng = np.random.default_rng(seed)
    a0 = rng.uniform(1, 2, n).astype(np.float32)
    b0 = rng.uniform(1, 2, n).astype(np.float32)
    b1 = b0.copy()
    sl = slice(100, 700)
    b1[sl] = rng.uniform(1, 2, 600).astype(np.float32)
    b1[-5:] = rng.uniform(1, 2, 5).astype(np.float32)  # dirty tail chunk
    merged = D.apply_leaf(a0, D.diff_leaf(b0, b1, op=op))
    # dirty chunks follow Table 3; clean chunks keep a0
    full = D.merge_scalarwise(a0, b0, b1, op)
    chunks = -(-n // D.CHUNK)
    fb0 = np.pad(b0, (0, chunks * D.CHUNK - n))
    fb1 = np.pad(b1, (0, chunks * D.CHUNK - n))
    dirty = np.any(fb0.reshape(-1, D.CHUNK) != fb1.reshape(-1, D.CHUNK),
                   axis=1)
    mask = np.repeat(dirty, D.CHUNK)[:n]
    np.testing.assert_array_equal(merged[mask], full[mask])
    np.testing.assert_array_equal(merged[~mask], a0[~mask])


def test_int64_sum_exact_beyond_f53():
    """Integer leaves merge exactly — the old float64 round-trip lost
    low bits above 2**53."""
    a0 = np.array([2 ** 60 + 1, 5], dtype=np.int64)
    b0 = np.array([2 ** 60 + 1, 5], dtype=np.int64)
    b1 = np.array([2 ** 60 + 4, 5], dtype=np.int64)
    got = D.apply_leaf(a0, D.diff_leaf(b0, b1, op="sum"))
    assert got.tolist() == [2 ** 60 + 4, 5]
    # the pinned reference demonstrates the old corruption
    ref = D.reference_apply_leaf(a0, D.reference_diff_leaf(b0, b1,
                                                           op="sum"))
    assert ref.tolist() != got.tolist()


def test_apply_leaf_empty_diff_passthrough_and_inplace():
    a = np.arange(5000, dtype=np.float32)
    d = D.diff_leaf(a, a.copy())
    assert D.apply_leaf(a, d) is a          # satellite 2: no copy
    b0 = a.copy()
    b1 = a.copy()
    b1[10:20] += 1
    d = D.diff_leaf(b0, b1, op="overwrite")
    out = D.apply_leaf(a, d, inplace=True)
    assert out is a
    np.testing.assert_array_equal(a, b1)


# ---------------------------------------------------------------------------
# apply_many: N-way merge == sequential application
# ---------------------------------------------------------------------------
@hc.hyp_or_examples(
    lambda st: (st.sampled_from(["sum", "overwrite", "multiply"]),
                st.integers(0, 2 ** 16)),
    examples=[("sum", 0), ("overwrite", 1), ("multiply", 2), ("sum", 3)])
def test_apply_many_matches_sequential(op, seed):
    n = 9000
    rng = np.random.default_rng(seed)
    a0 = rng.uniform(1, 2, n).astype(np.float32)
    b0 = a0.copy()
    diffs = []
    for k in range(4):
        b1 = b0.copy()
        # overlapping dirty ranges across workers exercise the
        # first-touch bookkeeping
        lo = 500 * k
        b1[lo:lo + 2000] = rng.uniform(1, 2, 2000).astype(np.float32)
        diffs.append(D.diff_leaf(b0, b1, op=op))
    seq = a0.copy()
    for d in diffs:
        seq = D.apply_leaf(seq, d)
    np.testing.assert_array_equal(D.apply_many(a0, diffs), seq)
    ip = a0.copy()
    assert D.apply_many(ip, diffs, inplace=True) is ip
    np.testing.assert_array_equal(ip, seq)


def test_apply_many_ragged_tail_and_full_coverage():
    n = D.CHUNK * 3 + 17
    rng = np.random.default_rng(5)
    a0 = rng.normal(size=n).astype(np.float32)
    b0 = a0.copy()
    d1_new = b0.copy(); d1_new[: 2 * D.CHUNK] += 1.0
    d2_new = b0.copy(); d2_new[2 * D.CHUNK:] += 2.0   # covers the tail
    diffs = [D.diff_leaf(b0, d1_new, op="sum"),
             D.diff_leaf(b0, d2_new, op="sum")]
    seq = D.apply_leaf(D.apply_leaf(a0, diffs[0]), diffs[1])
    np.testing.assert_array_equal(D.apply_many(a0, diffs), seq)


# ---------------------------------------------------------------------------
# TrackedFork: chunk-granular CoW write tracking (the mprotect analogue)
# ---------------------------------------------------------------------------
def test_tracked_fork_diff_matches_compare_based():
    rng = np.random.default_rng(7)
    base = rng.normal(size=10000).astype(np.float32)
    keep = base.copy()
    f = D.TrackedFork(base)
    np.multiply(base[100:3000], 1.5,
                out=f.writable(slice(100, 3000)))
    f[5000] = 9.0
    f[9999] = -1.0                          # last (ragged-size) element
    child = base.copy()
    child[100:3000] *= 1.5
    child[5000] = 9.0
    child[9999] = -1.0
    d = f.diff(op="overwrite")
    np.testing.assert_array_equal(base, keep)   # base never written
    got = D.apply_leaf(base, d)
    np.testing.assert_array_equal(got, child)
    # tracked mask is chunk-granular: same chunks a compare would find
    ref = D.diff_leaf(base, child, op="overwrite")
    np.testing.assert_array_equal(d.idx, ref.idx)


def test_tracked_fork_verify_drops_clean_writes():
    base = np.zeros(4096, dtype=np.float32)
    f = D.TrackedFork(base)
    f[0:1024] = 0.0                          # written but unchanged
    f[2048] = 5.0
    assert f.dirty_chunks.tolist() == [0, 2]
    assert f.diff(op="overwrite", verify=True).idx.tolist() == [2]


def test_tracked_fork_read_through():
    base = np.arange(3000, dtype=np.float32)
    f = D.TrackedFork(base)
    f[1500] = -1.0
    np.testing.assert_array_equal(f[0:10], base[0:10])   # clean read
    got = f[1400:1600]                       # straddles dirty chunk
    expect = base[1400:1600].copy()
    expect[100] = -1.0
    np.testing.assert_array_equal(got, expect)


# ---------------------------------------------------------------------------
# dense_merge dtype preservation (satellite 1)
# ---------------------------------------------------------------------------
def test_dense_merge_preserves_f64_precision():
    from jax.experimental import enable_x64
    with enable_x64():
        import jax.numpy as jnp
        old = np.full(2048, 1.0, dtype=np.float64)
        new = old + 1e-12                    # invisible in float32
        mask, delta = D.dense_diff(jnp.asarray(old), jnp.asarray(new))
        merged = D.dense_merge(jnp.asarray(old), mask, delta, op="sum")
        assert merged.dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(merged), new)


def test_dense_merge_int_exact():
    import jax.numpy as jnp
    old = (np.arange(3000, dtype=np.int32) * 1000003)
    new = old.copy()
    new[100:300] += 7
    mask, delta = D.dense_diff(jnp.asarray(old), jnp.asarray(new))
    merged = D.dense_merge(jnp.asarray(old), mask, delta, op="sum")
    assert merged.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(merged), new)


# ---------------------------------------------------------------------------
# fused_diff_apply: host path vs kernels/diff_merge routing
# ---------------------------------------------------------------------------
def test_fused_diff_apply_host_vs_kernel():
    rng = np.random.default_rng(11)
    a0 = rng.normal(size=(64, 300)).astype(np.float32)
    fork = a0.copy()
    child = fork.copy()
    child[3, :50] += 1.0
    mh, dh = D.fused_diff_apply(a0, fork, child, op="sum",
                                use_kernel=False)
    mk, dk = D.fused_diff_apply(a0, fork, child, op="sum",
                                use_kernel=True, interpret=True)
    np.testing.assert_allclose(mh, np.asarray(mk), atol=1e-6)
    np.testing.assert_array_equal(dh, np.asarray(dk))
    assert dh.sum() == 1
