"""Cluster scheduler facade: chip-granular gang allocation (paper §3.4).

The paper's headline mechanism is scheduling threads/processes at vCPU
granularity onto shared VMs instead of dedicating whole VMs.  The TPU
adaptation schedules *Granules* (one per chip) onto shared hosts.

All placement mechanics live in ``core.placement``: ``PlacementEngine``
owns the free-chip accounting, gang allocation, reservations, and
barrier-point migration planning, and ``PlacementPolicy`` implementations
(binpack / spread / locality / fixed-slice) decide where a gang lands.
``ClusterState`` survives as the thin facade the rest of the repo (and
the tests) already speak:

* ``alloc_granular`` — policy-driven chip-granular gang allocation
  (default: Faabric's binpack).
* ``alloc_slices``  — the fixed-slice baselines of §6.2 (the "k
  containers per VM" baselines); a job takes whole slices.
* ``migration_plan`` — at barrier control points, find fragmented gangs
  that now fit on fewer hosts and emit Granule moves (paper §3.3, Fig 8).
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple, Union

from repro.core.placement import (Allocation, FixedSlicePolicy,
                                  PlacementEngine, PlacementPolicy,
                                  ShardedPlacementEngine)

__all__ = ["Allocation", "ClusterState"]


class ClusterState:
    """Free-chip accounting for a cluster of hosts — a facade over
    ``PlacementEngine`` keeping the original call signatures.
    ``capacities``/``speeds`` open the heterogeneous-fleet path (ragged
    hosts, mixed generations) without changing any caller;
    ``shard_hosts`` runs the facade over the decentralised
    ``ShardedPlacementEngine`` (host groups of that size) — same
    signatures, O(shard) decisions."""

    def __init__(self, hosts: int, chips_per_host: int,
                 capacities: Optional[Sequence[int]] = None,
                 speeds: Optional[Sequence[float]] = None,
                 shard_hosts: Optional[int] = None):
        if shard_hosts is None:
            self.engine = PlacementEngine(hosts, chips_per_host,
                                          capacities=capacities,
                                          speeds=speeds)
        else:
            self.engine = ShardedPlacementEngine(
                hosts, chips_per_host, hosts_per_shard=shard_hosts,
                capacities=capacities, speeds=speeds)
        self.hosts = hosts
        self.chips_per_host = chips_per_host

    # ---- capacity ----------------------------------------------------------
    @property
    def free(self):
        return self.engine.free

    @property
    def capacities(self):
        return self.engine.capacities

    @property
    def speeds(self):
        return self.engine.speeds

    @property
    def jobs_on_host(self):
        return self.engine.jobs_on_host

    @property
    def total_chips(self) -> int:
        return self.engine.total_chips

    def idle_chips(self) -> int:
        return self.engine.idle_chips()

    def idle_fraction(self) -> float:
        return self.engine.idle_fraction()

    def idle_throughput(self) -> float:
        return self.engine.idle_throughput()

    # ---- allocation ----------------------------------------------------------
    def alloc_granular(self, job_id: str, n: int,
                       policy: Union[str, PlacementPolicy] = "binpack",
                       kind: Optional[str] = None) -> Optional[Allocation]:
        """Chip-granular gang allocation under a named placement policy
        (binpack / spread / locality) or a ``PlacementPolicy`` instance;
        ``kind`` routes the job's per-kind beta into model-scoring
        policies."""
        return self.engine.allocate(job_id, n, policy=policy, kind=kind)

    def alloc_slices(self, job_id: str, n_chips: int,
                     slice_size: int) -> Optional[Allocation]:
        """Whole-slice allocation: ceil(n/slice) slices, each on one host."""
        return self.engine.allocate(job_id, n_chips,
                                    policy=FixedSlicePolicy(slice_size))

    # ---- free ----------------------------------------------------------------
    def release(self, alloc: Allocation) -> None:
        self.engine.release(alloc)

    # ---- migration (defragmentation at barrier points) ------------------------
    def migration_plan(self, allocs: Sequence[Allocation],
                       kinds: Optional[dict] = None,
                       remaining: Optional[dict] = None
                       ) -> List[Tuple[str, List[Tuple[int, int]]]]:
        return self.engine.migration_plan(allocs, kinds=kinds,
                                          remaining=remaining)

    def apply_migration(self, alloc: Allocation,
                        new_placement: List[Tuple[int, int]]) -> Allocation:
        return self.engine.apply_migration(alloc, new_placement)
