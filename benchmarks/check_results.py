"""CI gate: every standardized benchmark artifact in results/ must
parse as JSON and carry a non-empty ``metrics`` table (schema in
``benchmarks/run.py``).  Covers both the committed full-size
``BENCH_*.json`` trajectory and freshly-produced ``SMOKE_*.json``."""
from __future__ import annotations

import glob
import json
import os
import sys

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results")


def main() -> int:
    paths = sorted(glob.glob(os.path.join(RESULTS_DIR, "BENCH_*.json"))
                   + glob.glob(os.path.join(RESULTS_DIR,
                                            "SMOKE_*.json")))
    if not paths:
        print("no BENCH_*/SMOKE_* artifacts found", file=sys.stderr)
        return 1
    bad = 0
    for path in paths:
        name = os.path.basename(path)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            print(f"FAIL {name}: unreadable ({e})", file=sys.stderr)
            bad += 1
            continue
        metrics = payload.get("metrics")
        if not isinstance(metrics, dict) or not metrics:
            print(f"FAIL {name}: empty or missing metrics",
                  file=sys.stderr)
            bad += 1
            continue
        print(f"ok   {name}: {len(metrics)} metrics "
              f"(bench={payload.get('bench')}, "
              f"wall={payload.get('wall_s')}s)")
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
