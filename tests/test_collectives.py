"""Placement-aware collective dispatch (DESIGN.md §11): the analytic
cost model in ``core.comms``, the ``CollectiveTuner`` dispatch table and
its Fabric/GangHandle re-derivation hooks, HLO slow-link accounting, the
threshold-select codec inside the compressed schedule, and the
``CostModel.collective_time`` pricing that feeds placement scoring.

Pure pieces run in-process; anything needing a (pod, data) mesh runs in
an 8-device subprocess (same pattern as test_dist)."""
import os
import subprocess
import sys
import textwrap

import numpy as np

from repro.core import comms
from repro.core.collectives import CollectiveTuner
from repro.core.placement import (ClusterView, CostModel,
                                  LocalityScoredPolicy,
                                  placement_cross_host_fraction)

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_sub(code: str, devices: int = 8, timeout: int = 1200) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# ---------------------------------------------------------------------------
# comms: analytic cost model (pure)
# ---------------------------------------------------------------------------
def test_topology_from_placement():
    t = comms.Topology.from_placement([(0, 4), (1, 4)])
    assert (t.hosts, t.chips, t.min_fast) == (2, 8, 4)
    t = comms.Topology.from_placement([(3, 6), (0, 1), (5, 1)])
    assert (t.hosts, t.chips, t.min_fast) == (3, 8, 1)


def test_size_bucket_clamped_log2():
    assert comms.size_bucket(1) == comms.MIN_BUCKET
    assert comms.size_bucket(1 << 20) == 20
    assert comms.size_bucket((1 << 20) + 1) == 21
    assert comms.size_bucket(1 << 40) == comms.MAX_BUCKET
    assert comms.size_bucket(None) == comms.size_bucket(comms.DEFAULT_NBYTES)


def test_schedule_cost_orderings():
    topo = comms.Topology(hosts=2, chips=8, min_fast=4)
    link = comms.LinkProfile()
    big = 16 << 20
    # two-level beats flat on any multi-host topology at large sizes:
    # the slow hop ships bytes/min_fast instead of the whole vector
    assert comms.schedule_cost(topo, big, "hierarchical", link) \
        < comms.schedule_cost(topo, big, "flat", link)
    # compressed beats hierarchical at large sizes (2*frac of the shard)
    assert comms.schedule_cost(topo, big, "compressed", link, frac=0.05) \
        < comms.schedule_cost(topo, big, "hierarchical", link)
    # at tiny sizes per-step latency dominates: flat wins
    assert comms.schedule_cost(topo, 256, "flat", link) \
        < comms.schedule_cost(topo, 256, "compressed", link, frac=0.05)
    # compressed needs a pod axis
    assert comms.schedule_cost(comms.Topology(1, 8, 8), big, "compressed",
                               link, frac=0.05) == float("inf")
    # a ragged split prices worse than a balanced one (smaller min_fast)
    ragged = comms.Topology(2, 8, 1)
    assert comms.schedule_cost(topo, big, "hierarchical", link) \
        < comms.schedule_cost(ragged, big, "hierarchical", link)


def test_best_schedule_and_crossover():
    topo = comms.Topology(2, 8, 4)
    link = comms.LinkProfile()
    mode_small, _ = comms.best_schedule(topo, 256, link, 0.05)
    mode_big, _ = comms.best_schedule(topo, 64 << 20, link, 0.05)
    assert mode_small == "flat" and mode_big == "compressed"
    cross = comms.crossover_bytes(topo, "flat", "compressed", link, 0.05)
    assert cross > 0
    assert comms.schedule_cost(topo, 2 * cross, "compressed", link, 0.05) \
        < comms.schedule_cost(topo, 2 * cross, "flat", link)
    # measured overrides beat the analytic estimate
    mode, t = comms.best_schedule(topo, 64 << 20, link, 0.05,
                                  measured={"compressed": 1e3})
    assert mode != "compressed"


# ---------------------------------------------------------------------------
# CollectiveTuner dispatch (pure)
# ---------------------------------------------------------------------------
def test_tuner_dispatch_by_size_and_topology():
    tuner = CollectiveTuner()
    two_host = [(0, 4), (1, 4)]
    assert tuner.mode_for(two_host, 1 << 10) == "flat"
    assert tuner.mode_for(two_host, 64 << 20) == "compressed"
    # single host: no slow link, flat always wins
    for nbytes in (1 << 10, 64 << 20):
        assert tuner.mode_for([(0, 8)], nbytes) == "flat"
    # allowed restricts the choice (single-axis mesh: no pod schedules)
    assert tuner.mode_for(two_host, 64 << 20,
                          allowed=("flat", "ring")) in ("flat", "ring")


def test_tuner_placement_change_rederives_all_buckets():
    tuner = CollectiveTuner()
    topo = tuner.on_placement_change("j0", [(0, 4), (1, 4)])
    assert tuner.gangs["j0"] == topo and tuner.rederivations == 1
    n_buckets = comms.MAX_BUCKET - comms.MIN_BUCKET + 1
    assert sum(1 for (key, _) in tuner.table if key == topo.key) \
        == n_buckets
    # dispatch by job id follows the gang's recorded topology
    assert tuner.mode_for("j0", 64 << 20) == "compressed"
    # migration to a single host flips every bucket to flat
    tuner.on_placement_change("j0", [(2, 8)])
    assert tuner.rederivations == 2
    assert tuner.mode_for("j0", 64 << 20) == "flat"
    tuner.forget("j0")
    assert "j0" not in tuner.gangs


def test_tuner_probe_overrides_analytic():
    tuner = CollectiveTuner()
    pl = [(0, 4), (1, 4)]
    nbytes = 64 << 20
    assert tuner.mode_for(pl, nbytes) == "compressed"
    # a probe that measures compressed as catastrophically slow (say the
    # fleet's codec offload is broken) re-derives the dispatch entry
    tuner.record_probe(pl, nbytes, "compressed", 1e3)
    assert tuner.mode_for(pl, nbytes) == "hierarchical"
    assert tuner.predicted_time(pl, nbytes) \
        == comms.schedule_cost(comms.Topology.from_placement(pl),
                               comms.bucket_nbytes(comms.size_bucket(nbytes)),
                               "hierarchical", tuner.link)


# ---------------------------------------------------------------------------
# CostModel.collective_time pricing (pure)
# ---------------------------------------------------------------------------
def test_collective_time_prefers_balanced_splits():
    cm = CostModel(collective_bytes=64 << 20, step_compute_s=0.05)
    single = cm.collective_time([(0, 8)])
    balanced = cm.collective_time([(0, 4), (1, 4)])
    ragged = cm.collective_time([(0, 6), (1, 1), (2, 1)])
    assert single < balanced < ragged
    assert cm.slowdown([(0, 8)]) < cm.slowdown([(0, 4), (1, 4)])


def test_collective_pricing_off_is_bit_identical():
    # default CostModel keeps the exact pre-PR scalar-beta slowdown
    cm = CostModel()
    assert not cm.collective_pricing
    for pl in ([(0, 8)], [(0, 4), (1, 4)], [(0, 6), (1, 2)]):
        for kind in (None, "mpi-network", "omp"):
            assert cm.slowdown(pl, kind) == 1.0 + cm.beta(kind) \
                * placement_cross_host_fraction(pl)


def test_collective_priced_policy_picks_balanced_split():
    cm = CostModel(collective_bytes={"mpi-network": 64 << 20},
                   step_compute_s=0.01)
    pol = LocalityScoredPolicy(cost_model=cm)
    scalar = LocalityScoredPolicy(beta=13.0)
    free = np.array([7, 7, 7, 0], dtype=np.int64)
    a = pol.place(ClusterView(free.copy(), 8), 15, kind="mpi-network")
    b = scalar.place(ClusterView(free.copy(), 8), 15, kind="mpi-network")
    # greedy most-free gives the ragged {7,7,1}; only the collective
    # score can rank the balanced {5,5,5} candidate above it
    assert sorted(c for _, c in a) == [5, 5, 5]
    assert min(c for _, c in b) == 1
    # either way the gang is whole
    assert sum(c for _, c in a) == sum(c for _, c in b) == 15


def test_balanced_split_respects_caps():
    pol = LocalityScoredPolicy()
    free = np.array([7, 3, 3, 2], dtype=np.int64)
    pl = pol._balanced_split(free, 12)
    assert sum(c for _, c in pl) == 12
    assert all(c <= free[h] for h, c in pl)
    assert len(pl) == 3                    # fewest hosts that fit
    assert pol._balanced_split(free, 16) is None


def test_hlo_accounting_tuple_shapes_and_operand_mentions():
    from repro.core import collectives as C
    hlo = """
    ENTRY %main {
      %p0 = f32[256]{0} parameter(0)
      %cp = (f32[256]{0:T(256)}, f32[128]{0}) collective-permute(%p0), source_target_pairs={{0,1},{1,2}}
      %fusion = f32[256]{0} fusion(%collective-permute.1), kind=kLoop
      %ar = f32[64]{0} all-reduce(%p0), replica_groups={{0,1,2,3}}
    }
    """
    got = C.collective_bytes_from_hlo(hlo)
    # tuple-shaped permute results count every element (256+128 f32);
    # the fusion line *mentioning* a collective-permute operand doesn't
    assert got["collective-permute"] == (256 + 128) * 4
    assert got["all-reduce"] == 64 * 4
    assert got["total"] == (256 + 128 + 64) * 4
    # slow-link view: pods [0,0,1,1] -> the 1->2 hop crosses but 0->1
    # doesn't (half the pairs), and the all-reduce group spans pods
    slow = C.slowlink_bytes_from_hlo(hlo, [0, 0, 1, 1])
    assert slow == (256 + 128) * 4 // 2 + 64 * 4
    # a single-pod fleet has no slow link at all
    assert C.slowlink_bytes_from_hlo(hlo, [0, 0, 0, 0]) == 0


# ---------------------------------------------------------------------------
# mesh-level: schedules, codec bit-exactness, HLO accounting, hooks
# ---------------------------------------------------------------------------
def test_all_modes_agree_and_frac1_bit_exact():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import collectives as C
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        tree = {"a": jax.random.normal(jax.random.PRNGKey(0), (8, 4, 33)),
                "b": jax.random.normal(jax.random.PRNGKey(1), (8, 257))}
        outs = {}
        for mode in ("flat", "ring", "hierarchical"):
            f = jax.jit(C.build_tree_allreduce(mesh, mode=mode))
            outs[mode] = jax.tree.leaves(f(tree, None)[0])
        for mode in ("ring", "hierarchical"):
            for o, e in zip(outs[mode], outs["flat"]):
                np.testing.assert_allclose(np.asarray(o), np.asarray(e),
                                           atol=1e-5)
        # frac=1.0: every element selected, m=1 chunks — the compressed
        # schedule reduces to hierarchical bit-for-bit
        f = jax.jit(C.build_tree_allreduce(mesh, mode="compressed",
                                           compress_frac=1.0))
        resid = C.init_residual_buffer(mesh, jax.tree.map(lambda x: x[0],
                                                          tree))
        out, resid = f(tree, resid)
        for o, e in zip(jax.tree.leaves(out), outs["hierarchical"]):
            np.testing.assert_array_equal(np.asarray(o), np.asarray(e))
        for r in jax.tree.leaves(resid):
            assert not np.asarray(r).any()
        print("modes-ok")
    """))


def test_slowlink_bytes_measured_from_hlo():
    print(run_sub("""
        import jax
        from repro.core import collectives as C
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        nbytes = 4096
        slow = {m: C.measure_schedule(mesh, m, nbytes, reps=1)
                     ["slowlink_bytes"] for m in
                ("flat", "ring", "hierarchical", "compressed")}
        # flat ships every chip's full shard across the pod boundary;
        # the two-level schedule ships 1/min_fast of it
        assert slow["flat"] == 4 * slow["hierarchical"], slow
        # ring's p2p hops cross the boundary for a fraction of steps but
        # still move the whole vector through the slow link overall
        assert slow["ring"] == slow["flat"], slow
        # the codec ships 2*frac of the shard (values + indices)
        assert 0 < slow["compressed"] < slow["hierarchical"], slow
        print("slowlink-ok", slow)
    """))


def test_ppermute_slowlink_counts_crossing_fraction():
    print(run_sub("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P
        from repro.core import collectives as C
        from repro.core.compat import make_mesh, shard_map
        mesh = make_mesh((2, 4), ("pod", "data"))
        # ring over ALL 8 devices: 2 of 8 hops cross the pod boundary
        def body(v):
            perm = [(i, (i + 1) % 8) for i in range(8)]
            return jax.lax.ppermute(v, ("pod", "data"), perm)
        f = jax.jit(shard_map(body, mesh=mesh, in_specs=P(("pod","data")),
                              out_specs=P(("pod","data")),
                              check_vma=False))
        x = jnp.ones((8, 256), jnp.float32)
        hlo = f.lower(x).compile().as_text()
        got = C.slowlink_bytes_from_hlo(hlo, C.mesh_pod_of(mesh))
        # per-chip shard is 256 f32 = 1024 B; 2/8 of the hops cross
        assert got == int(1024 * 2 / 8), (got, 256)
        print("ppermute-ok", got)
    """))


def test_fabric_hooks_rederive_tuner():
    print(run_sub("""
        import jax, jax.numpy as jnp
        from repro.core.fabric import Fabric
        mesh_state = {"w": jnp.zeros((4, 4))}
        fab = Fabric(chips_per_host=2)
        h = fab.bind("j0", fab.devices[:4], pods=2)
        tuner = fab.tuner
        assert "j0" in tuner.gangs
        base = tuner.rederivations
        assert base >= 1
        # a rescale re-derives the gang's dispatch entries
        state = jax.device_put(mesh_state)
        state = h.rescale(state, 8)
        assert tuner.rederivations > base
        assert "j0" in tuner.gangs
        # best_sync_mode consults the tuner for the gang's placement;
        # a two-pod gang may use any schedule, and a huge message routes
        # to a slow-link-avoiding one
        m = h.best_sync_mode(64 << 20)
        assert m in ("flat", "ring", "hierarchical", "compressed")
        assert m != "flat"
        h.release()
        assert "j0" not in tuner.gangs
        print("hooks-ok", m)
    """))


def test_compressed_error_feedback_converges_frac01():
    print(run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import collectives as C
        from repro.core.compat import make_mesh
        mesh = make_mesh((2, 4), ("pod", "data"))
        tree = {"g": jax.random.normal(jax.random.PRNGKey(2), (8, 96))}
        f = jax.jit(C.build_tree_allreduce(mesh, mode="compressed",
                                           compress_frac=0.1))
        resid = C.init_residual_buffer(mesh, jax.tree.map(lambda x: x[0],
                                                          tree))
        expect = jnp.broadcast_to(tree["g"].mean(0), tree["g"].shape)
        total = jnp.zeros_like(tree["g"])
        errs = {}
        for step in range(1, 25):
            out, resid = f(tree, resid)
            total = total + out["g"]
            if step in (6, 24):
                errs[step] = float(jnp.abs(total / step - expect).max()
                                   / jnp.abs(expect).max())
        # error feedback: the residual is bounded, so the running mean
        # converges to the true mean ~ 1/steps
        assert errs[24] < errs[6] / 2, errs
        assert errs[24] < 0.25, errs
        print("ef-ok", errs)
    """))


def test_flatten_spec_cache_and_single_split_unflatten():
    import jax
    import jax.numpy as jnp
    from repro.core import collectives as C
    tree = {"a": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones((5,))}
    C._SPEC_CACHE.clear()
    vec, spec = C.flatten_tree(tree)
    assert len(C._SPEC_CACHE) == 1
    vec2, spec2 = C.flatten_tree(jax.tree.map(lambda x: x * 2, tree))
    assert len(C._SPEC_CACHE) == 1 and spec2 is spec   # cache hit
    out = C.unflatten_tree(vec, spec)
    for o, e in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(e))
    # a different structure misses and adds one entry
    C.flatten_tree({"c": jnp.ones((3, 3))})
    assert len(C._SPEC_CACHE) == 2
    # padded flatten roundtrips too
    vec, spec = C.flatten_tree(tree, pad_to=8)
    assert vec.size % 8 == 0
    out = C.unflatten_tree(vec, spec)
    for o, e in zip(jax.tree.leaves(out), jax.tree.leaves(tree)):
        np.testing.assert_array_equal(np.asarray(o), np.asarray(e))
