"""Model-math invariants: fused loss == naive loss, blocked attention ==
full attention, chunked scans == recurrences, MoE capacity semantics,
sharding-spec validity for every arch x mesh."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_IDS, get_config, reduced_config
from repro.models import attention as A
from repro.models import layers as L
from repro.models import model as M
from repro.models import shardings as SH
from repro.models import xlstm as X
from repro.models.ssm import ssd_chunked
from repro.kernels.mamba_scan.ref import ssd_ref

key = jax.random.PRNGKey(0)
sub = lambda i: jax.random.fold_in(key, i)


def test_fused_unembed_xent_matches_naive():
    b, s, d, v = 2, 64, 32, 101
    x = jax.random.normal(sub(1), (b, s, d))
    head = jax.random.normal(sub(2), (d, v)) * 0.1
    labels = jax.random.randint(sub(3), (b, s), 0, v)
    naive = L.softmax_xent(x @ head, labels, v)
    fused = L.fused_unembed_xent(x, head, labels, chunk=16)
    scan = L.fused_unembed_xent_scan(x, head, labels, chunk=16)
    np.testing.assert_allclose(float(naive), float(fused), rtol=1e-6)
    np.testing.assert_allclose(float(naive), float(scan), rtol=1e-6)


def test_fused_xent_gradients_match():
    b, s, d, v = 2, 32, 16, 50
    x = jax.random.normal(sub(4), (b, s, d))
    head = jax.random.normal(sub(5), (d, v)) * 0.1
    labels = jax.random.randint(sub(6), (b, s), 0, v)
    g1 = jax.grad(lambda h: L.softmax_xent(x @ h, labels, v))(head)
    g2 = jax.grad(lambda h: L.fused_unembed_xent(x, h, labels,
                                                 chunk=8))(head)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-5)


def test_blocked_attention_matches_full():
    b, s, h, kv, hd = 2, 512, 4, 2, 32
    q = jax.random.normal(sub(7), (b, s, h, hd))
    k = jax.random.normal(sub(8), (b, s, kv, hd))
    v = jax.random.normal(sub(9), (b, s, kv, hd))
    full = A.sdpa(q, k, v, causal=True)
    blocked = A.sdpa_blocked(q, k, v, block_q=128)
    scan = A.sdpa_blocked_scan(q, k, v, block_q=128)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(full), np.asarray(scan),
                               atol=2e-5)


def test_blocked_attention_window():
    b, s, h, hd = 1, 256, 2, 16
    q = jax.random.normal(sub(10), (b, s, h, hd))
    k = jax.random.normal(sub(11), (b, s, h, hd))
    v = jax.random.normal(sub(12), (b, s, h, hd))
    full = A.sdpa(q, k, v, causal=True, window=64)
    blocked = A.sdpa_blocked(q, k, v, window=64, block_q=64)
    np.testing.assert_allclose(np.asarray(full), np.asarray(blocked),
                               atol=2e-5)


def test_ring_buffer_decode_matches_window_attention():
    """Windowed ring-buffer decode == full-cache windowed attention."""
    cfg = reduced_config("zamba2-2.7b")
    params = jax.jit(lambda k: A.init_attention(k, cfg))(sub(13))
    b, s, window = 2, 32, 8
    x = jax.random.normal(sub(14), (b, s, cfg.d_model))
    pos = jnp.arange(s)[None, :]
    full, _ = A.attention(params, x, cfg, pos, causal=True, window=window)
    cache = A.init_kv_cache(cfg, b, s, x.dtype, window=window)
    outs = []
    for t in range(s):
        o, cache = A.decode_attention(params, x[:, t:t + 1], cache, cfg,
                                      jnp.full((b, 1), t), window=window)
        outs.append(o[:, 0])
    np.testing.assert_allclose(np.asarray(jnp.stack(outs, 1)),
                               np.asarray(full), atol=2e-4, rtol=1e-3)


def test_ssd_chunked_matches_recurrence():
    b, l, h, p, n = 2, 64, 3, 8, 4
    x = jax.random.normal(sub(15), (b, l, h, p)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(sub(16), (b, l, h)))
    a = -jnp.exp(jax.random.normal(sub(17), (h,)) * 0.3)
    bb = jax.random.normal(sub(18), (b, l, n)) * 0.5
    cc = jax.random.normal(sub(19), (b, l, n)) * 0.5
    y1, s1 = ssd_chunked(x, dt, a, bb, cc, chunk=16)
    y2, s2 = ssd_ref(jnp.moveaxis(x, 2, 1), jnp.moveaxis(dt, 2, 1)[..., None],
                     a[:, None, None], bb, cc)
    np.testing.assert_allclose(np.asarray(y1),
                               np.asarray(jnp.moveaxis(y2, 1, 2)),
                               atol=5e-4, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=1e-4,
                               rtol=1e-3)


def test_mlstm_chunk_size_invariance():
    b, l, h, hd = 2, 64, 2, 16
    q = jax.random.normal(sub(20), (b, l, h, hd))
    k = jax.random.normal(sub(21), (b, l, h, hd))
    v = jax.random.normal(sub(22), (b, l, h, hd))
    li = jax.random.normal(sub(23), (b, l, h)) - 1
    lf = -jax.nn.softplus(jax.random.normal(sub(24), (b, l, h)))
    o1, s1 = X.mlstm_chunked(q, k, v, li, lf, chunk=64)
    o2, s2 = X.mlstm_chunked(q, k, v, li, lf, chunk=1)   # pure recurrence
    o3, s3 = X.mlstm_chunked(q, k, v, li, lf, chunk=16, use_scan=True)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o3), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s1[0]), np.asarray(s2[0]),
                               atol=1e-4)


def test_moe_capacity_drops_tokens():
    """Lower capacity factor must drop tokens (zeroed outputs), higher must
    not; gates renormalise over top-k."""
    from repro.models import moe as moe_mod
    cfg = reduced_config("granite-moe-1b-a400m")
    params = jax.jit(lambda k: moe_mod.init_moe(k, cfg))(sub(25))
    x = jax.random.normal(sub(26), (2, 64, cfg.d_model))
    y_hi, _ = jax.jit(lambda p, x: moe_mod.moe_ffn(
        p, x, cfg.with_(capacity_factor=8.0)))(params, x)
    y_lo, _ = jax.jit(lambda p, x: moe_mod.moe_ffn(
        p, x, cfg.with_(capacity_factor=0.25)))(params, x)
    # low capacity zeroes some token outputs
    zeros_lo = int((jnp.abs(y_lo).sum(-1) < 1e-9).sum())
    zeros_hi = int((jnp.abs(y_hi).sum(-1) < 1e-9).sum())
    assert zeros_lo > zeros_hi


# ---------------------------------------------------------------------------
# sharding specs: structural validity for every arch on both meshes
# ---------------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, shape, axes):
        self.shape = dict(zip(axes, shape))
        self.axis_names = axes


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh_shape,axes", [
    ((16, 16), ("data", "model")),
    ((2, 16, 16), ("pod", "data", "model")),
])
def test_param_specs_divisible(arch, mesh_shape, axes):
    cfg = get_config(arch).with_(fsdp=True)
    mesh = _FakeMesh(mesh_shape, axes)
    shapes = M.param_specs(cfg)
    specs = SH.param_pspecs(cfg, shapes, mesh)
    flat_s = jax.tree_util.tree_leaves_with_path(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: hasattr(x, "_normalized_spec") or str(type(x).__name__) == "PartitionSpec")
    assert len(flat_s) == len(flat_p)
    for (path, leaf), spec in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            ax_names = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in ax_names:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, jax.tree_util.keystr(path),
                                  leaf.shape, spec)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "glm4-9b", "zamba2-2.7b",
                                  "xlstm-1.3b", "whisper-small"])
def test_decode_state_specs_divisible(arch):
    from repro.configs.base import SHAPES
    cfg = get_config(arch)
    mesh = _FakeMesh((16, 16), ("data", "model"))
    shapes = M.decode_state_specs(cfg, SHAPES["decode_32k"])
    specs = SH.decode_state_pspecs(cfg, shapes, mesh)
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_p = jax.tree.leaves(specs, is_leaf=lambda x: str(type(x).__name__) == "PartitionSpec")
    for leaf, spec in zip(flat_s, flat_p):
        for dim, ax in zip(leaf.shape, tuple(spec)):
            if ax is None:
                continue
            ax_names = ax if isinstance(ax, tuple) else (ax,)
            n = 1
            for a in ax_names:
                n *= mesh.shape[a]
            assert dim % n == 0, (arch, leaf.shape, spec)
