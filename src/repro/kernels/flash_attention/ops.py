"""jit'd public wrapper for the flash_attention kernel.

Accepts model-layout tensors (B, S, H, hd) / (B, S, KV, hd), transposes to
the kernel's (B, H, S, hd) blocking layout, pads the head dim to a
lane-aligned multiple of 128 when necessary (e.g. zamba2's hd=80), and
selects interpret mode automatically off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import kernel as _k


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    interpret: bool | None = None):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd)."""
    if interpret is None:
        interpret = _interpret_default()
    b, s, h, hd = q.shape
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    pad = (-hd) % 128 if hd > 64 else (-hd) % 64
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad)))
        qt, kt, vt = zp(qt), zp(kt), zp(vt)
    block_q = min(_k.DEFAULT_BLOCK_Q, s)
    block_k = min(_k.DEFAULT_BLOCK_K, s)
    out = _k.flash_attention(qt, kt, vt, causal=causal, window=window,
                             block_q=block_q, block_k=block_k,
                             scale=hd ** -0.5,  # unpadded head dim
                             interpret=interpret)
    if pad:
        out = out[..., :hd]
    return jnp.swapaxes(out, 1, 2)
