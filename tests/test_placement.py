"""PlacementEngine invariants: migration-plan edge cases, preemption-safe
reservations, policy behaviour, and the multi-tenant simulator semantics
(arrival times, priority classes, backfill) built on top of it."""
import numpy as np
import pytest

from repro.core import simulator as S
from repro.core.elastic import ElasticPolicy
from repro.core.placement import (Allocation, BinpackPolicy,
                                  FixedSlicePolicy, LocalityScoredPolicy,
                                  PlacementEngine, resolve_policy)


# ---------------------------------------------------------------------------
# migration planning
# ---------------------------------------------------------------------------
def test_overlapping_migration_plans_do_not_double_book():
    """Two fragmented gangs whose naive consolidation targets the same
    host: plans are committed against a scratch free map, so applying
    every emitted plan must keep each host within capacity."""
    eng = PlacementEngine(2, 6)
    a = eng.bind("A", [(0, 2), (1, 2)])
    b = eng.bind("B", [(0, 2), (1, 2)])
    plans = dict(eng.migration_plan([a, b]))
    assert set(plans) == {"A", "B"}
    # both consolidate to a single host — but not the same one
    hosts_a = [h for h, _ in plans["A"]]
    hosts_b = [h for h, _ in plans["B"]]
    assert len(hosts_a) == 1 and len(hosts_b) == 1
    assert hosts_a != hosts_b
    for alloc, jid in ((a, "A"), (b, "B")):
        alloc = eng.apply_migration(alloc, plans[jid])
        assert alloc.fragmentation() == 1
    assert (eng.free >= 0).all()
    assert (eng.free <= eng.chips_per_host).all()
    assert eng.idle_chips() == eng.total_chips - 8


def test_slice_allocations_are_never_migrated():
    eng = PlacementEngine(2, 8)
    blockers = [eng.allocate(f"b{i}", 4) for i in range(2)]
    sliced = eng.allocate("s", 8, policy=FixedSlicePolicy(4))
    assert sliced.slice_size == 4
    assert sliced.fragmentation() == 2       # forced across both hosts
    for blk in blockers:
        eng.release(blk)
    # consolidation would now be possible, but slices must stay put
    assert eng.migration_plan([sliced]) == []


def test_plan_that_frees_zero_hosts_is_not_emitted():
    eng = PlacementEngine(2, 8)
    gang = eng.bind("g", [(0, 6), (1, 6)])
    # 12 chips cannot fit on one 8-chip host: any re-placement still
    # spans 2 hosts, i.e. frees nothing — no plan
    assert eng.migration_plan([gang]) == []


def test_migration_plan_consolidates_when_hosts_free_up():
    eng = PlacementEngine(2, 8)
    blockers = [eng.allocate(f"b{i}", 6) for i in range(2)]
    gang = eng.allocate("g", 4)              # 2 free chips on each host
    assert gang.fragmentation() == 2
    for blk in blockers:
        eng.release(blk)
    plans = eng.migration_plan([gang])
    assert plans and plans[0][0] == "g"
    new = eng.apply_migration(gang, plans[0][1])
    assert new.fragmentation() == 1 and new.n == 4


# ---------------------------------------------------------------------------
# reservations (preemption-safe allocation handshake)
# ---------------------------------------------------------------------------
def test_reservation_holds_chips_until_settled():
    eng = PlacementEngine(2, 4)
    res = eng.reserve(6)
    assert res is not None and res.n == 6
    assert eng.idle_chips() == 2
    # a competing allocation cannot steal the reserved chips
    assert eng.allocate("thief", 4) is None
    eng.cancel(res)
    assert eng.idle_chips() == 8
    assert eng.allocate("thief", 4) is not None


def test_reservation_commit_binds_job():
    eng = PlacementEngine(2, 4)
    res = eng.reserve(3)
    alloc = eng.commit(res, "j")
    assert alloc.n == 3 and eng.allocations["j"] is alloc
    assert any("j" in s for s in eng.jobs_on_host)
    with pytest.raises(AssertionError):
        eng.commit(res, "j2")                # already settled
    eng.release(alloc)
    assert eng.idle_chips() == 8 and "j" not in eng.allocations


def test_bind_rejects_oversubscription():
    eng = PlacementEngine(1, 4)
    eng.bind("a", [(0, 3)])
    with pytest.raises(AssertionError):
        eng.bind("b", [(0, 2)])


# ---------------------------------------------------------------------------
# policies
# ---------------------------------------------------------------------------
def test_resolve_policy_rejects_unknown():
    with pytest.raises(ValueError):
        resolve_policy("fifo")


def test_locality_prefers_best_fit_host():
    # free = [8, 3]: binpack (most-free-first) puts a 3-gang on host 0,
    # stranding 5 chips there; locality picks the exact-fit host 1
    eng = PlacementEngine(2, 8)
    eng.bind("t", [(1, 5)])
    view = eng.view()
    assert BinpackPolicy().place(view, 3) == [(0, 3)]
    assert LocalityScoredPolicy().place(view, 3) == [(1, 3)]


def test_locality_minimises_cross_host_fraction_when_split():
    # free = [4, 3, 3], n = 6: greedy most-free-first takes 4+2; a 3+3
    # split has higher chi, so locality must also choose 4+2 — and place
    # the remainder on a best-fit host
    eng = PlacementEngine(3, 4)
    eng.bind("t", [(1, 1), (2, 1)])
    pl = LocalityScoredPolicy().place(eng.view(), 6)
    sizes = sorted(c for _, c in pl)
    assert sizes == [2, 4]


def test_locality_beats_binpack_mean_chi_on_fragmented_trace():
    """Acceptance: strictly lower mean cross_host_fraction than binpack
    on a fragmented 100-job mixed trace."""
    jobs = S.mixed_trace(100, seed=7)
    bp = S.Simulator(16, 8, "granular", migrate=False,
                     policy="binpack").run(jobs)
    lc = S.Simulator(16, 8, "granular", migrate=False,
                     policy="locality").run(jobs)
    assert len(bp.exec_times) == 100 and len(lc.exec_times) == 100
    assert lc.mean_cross_host_fraction() < bp.mean_cross_host_fraction()


# ---------------------------------------------------------------------------
# multi-tenant simulator semantics
# ---------------------------------------------------------------------------
def test_arrival_times_are_respected():
    jobs = S.generate_trace(40, "mpi-compute", seed=5, arrival_rate=0.3)
    assert any(j.arrival > 0 for j in jobs)
    res = S.Simulator(8, 8, "granular").run(jobs)
    assert len(res.exec_times) == 40
    assert all(w >= 0 for w in res.waited)   # no job starts before arrival
    assert res.makespan >= max(j.arrival for j in jobs)


def test_explicit_default_trace_matches_plain_trace():
    jobs = S.generate_trace(50, "mpi-compute", seed=4)
    explicit = [S.Job(j.job_id, j.kind, j.parallelism, j.work,
                      arrival=0.0, priority=0) for j in jobs]
    r1 = S.Simulator(8, 8, "granular").run(jobs)
    r2 = S.Simulator(8, 8, "granular").run(explicit)
    assert r1.makespan == r2.makespan
    assert r1.exec_times == r2.exec_times


def test_priority_class_runs_first():
    # one 8-chip host, both jobs need all of it: the high-priority job
    # submitted second must still run first
    low = S.Job("low", "mpi-compute", 8, 400.0, priority=0)
    high = S.Job("high", "mpi-compute", 8, 800.0, priority=10)
    res = S.Simulator(1, 8, "granular").run([low, high])
    # completion order: high (exec 100s) then low (exec 50s)
    assert res.exec_times[0] == pytest.approx(100.0, rel=1e-6)
    assert res.exec_times[1] == pytest.approx(50.0, rel=1e-6)


def test_backfill_runs_small_job_past_blocked_head():
    j1 = S.Job("j1", "mpi-compute", 6, 600.0)
    j2 = S.Job("j2", "mpi-compute", 8, 800.0)      # blocked head-of-line
    j3 = S.Job("j3", "mpi-compute", 2, 200.0)      # fits beside j1
    fifo = S.Simulator(1, 8, "granular").run([j1, j2, j3])
    bf = S.Simulator(1, 8, "granular", backfill=True).run([j1, j2, j3])
    assert len(bf.exec_times) == 3
    assert bf.makespan < fifo.makespan
    # under backfill, j3 starts immediately (modulo scheduler latency)
    # instead of queueing behind the blocked j2
    assert sorted(bf.waited)[1] < 0.1
    assert sorted(fifo.waited)[1] > 10.0


def test_run_baselines_seed_makespan_ordering():
    """Acceptance: with all arrivals at t=0 and default priority, the
    seed's qualitative ordering holds — faabric beats the coarse slices
    and stays on par with the finest slicing (§6.2)."""
    jobs = S.generate_trace(100, "mpi-compute", seed=0)
    res = S.run_baselines(jobs, hosts=32)
    fa = res["faabric"].makespan
    assert fa < res["1-ctr-per-vm"].makespan
    assert fa < res["2-ctr-per-vm"].makespan
    assert fa < res["4-ctr-per-vm"].makespan
    assert abs(fa - res["8-ctr-per-vm"].makespan) \
        / res["8-ctr-per-vm"].makespan < 0.1


# ---------------------------------------------------------------------------
# elastic policy through the engine
# ---------------------------------------------------------------------------
def test_elastic_decide_goes_through_engine():
    eng = PlacementEngine(2, 4)
    tenant = eng.allocate("tenant", 3)
    pol = ElasticPolicy(min_world=1, max_world=64, target_free=0)
    # world 2 + 5 free -> budget 7 -> grow to 4 (reservation verified)
    assert pol.decide(2, eng) == 4
    assert eng.idle_chips() == 5             # reservation was cancelled
    # leaving 5 chips for other tenants caps the budget at 2 -> no change
    assert ElasticPolicy(target_free=5).decide(2, eng) is None
    # tenant pressure + a free-chip target forces a shrink
    eng.release(tenant)
    big = eng.allocate("big", 7)
    assert ElasticPolicy(target_free=3).decide(4, eng) == 2
    eng.release(big)


def test_locality_policy_usable_for_elastic_engine():
    eng = PlacementEngine(4, 8, policy="locality")
    a = eng.allocate("gang", 8)
    assert a.fragmentation() == 1
    assert ElasticPolicy(max_world=16).decide(8, eng) == 16
