"""The Faabric training runtime: gang execution with control points.

This is the *executable* (CPU-fabric / real-TPU) counterpart of the pjit
production path: a data-parallel gang of Granules — one per device — each
running the full model replica on its batch slice, synchronising gradients
with the paper's hierarchical (pod-leader) collective schedule via
shard_map, and passing through a **control point** at every step boundary
where the runtime may checkpoint, recover from failure, migrate, or
elastically rescale the gang (paper §3.2/§3.3).

Fault tolerance (paper §3.4, implemented): failure -> gang restart from the
latest snapshot; the deterministic (seed, step)-keyed data pipeline makes
recovery bit-exact.  Straggler mitigation: EWMA step-time detector triggers
a migrate action.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.checkpoint.manager import CheckpointManager
from repro.configs.base import ArchConfig
from repro.core import collectives as coll
from repro.core import compat
from repro.core import control as ctl
from repro.core import elastic as elastic_mod
from repro.core.granule import GranuleGroup, make_group_from_devices
from repro.core.placement import PlacementEngine
from repro.data import pipeline as dp
from repro.models import model as model_mod
from repro.optim import adamw


@dataclasses.dataclass
class RuntimeConfig:
    total_steps: int = 20
    sync_mode: str = "hierarchical"   # hierarchical | flat | ring | compressed
    compress_frac: float = 0.05
    checkpoint_every: int = 10
    ckpt_dir: str = "/tmp/repro-ckpt"
    chips_per_host: int = 4           # CPU-fabric host granularity
    incremental_ckpt_every: int = 0
    # fault injection: {step: description}; a failure at step s is detected
    # at the step-s control point and triggers gang restart from the latest
    # checkpoint.
    inject_failures: Dict[int, str] = dataclasses.field(default_factory=dict)
    # elastic schedule: {step: new_world_size}
    rescale_at: Dict[int, int] = dataclasses.field(default_factory=dict)
    pods: int = 1                     # >1: two-level gang (pod, data) mesh
    # gang placement policy on the host fabric (binpack/spread/locality)
    placement_policy: str = "binpack"
    # free-chip-driven elastic policy, consulted at every control point;
    # None = only the explicit rescale_at schedule fires
    elastic: Optional[elastic_mod.ElasticPolicy] = None


def make_gang_mesh(devices: Sequence[Any], pods: int = 1) -> Mesh:
    devs = np.asarray(list(devices))
    if pods > 1:
        return Mesh(devs.reshape(pods, -1), ("pod", "data"))
    return Mesh(devs, ("data",))


def make_dp_train_step(cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                       mesh: Mesh, mode: str,
                       compress_frac: Optional[float] = None) -> Callable:
    """Gang train step: per-device grads + explicit Faabric-style sync."""
    loss_fn = model_mod.make_loss_fn(cfg)
    gfn = jax.value_and_grad(loss_fn, has_aux=True)
    fast, slow = coll.dp_axes(mesh)
    axes = [a for a in (fast, slow) if a is not None]
    n_total = int(np.prod([mesh.shape[a] for a in axes]))

    def per_device(params, batch, resid):
        (_, metrics), grads = gfn(params, batch)
        rs = resid[0] if mode == "compressed" else None
        synced, new_rs = coll.tree_sync_body(
            grads, mode, fast, slow, n_total, compress_frac, rs)
        metrics = jax.tree.map(
            lambda m: jax.lax.pmean(m, tuple(axes)), metrics)
        return synced, metrics, (new_rs[None] if new_rs is not None
                                 else jnp.zeros((1, 1), jnp.float32))

    dp_spec = P(tuple(a for a in (("pod",) if slow else ()) + (fast,)))
    resid_spec = P(slow, fast) if slow else P(None, fast)

    def train_step(state, batch, resid):
        grads, metrics, new_resid = compat.shard_map(
            per_device, mesh=mesh,
            in_specs=(P(), jax.tree.map(
                lambda _: dp_spec, batch), resid_spec),
            out_specs=(P(), P(), resid_spec),
            check_vma=False)(state["params"], batch, resid)
        params, opt, om = adamw.apply(grads, state["opt"], state["params"],
                                      opt_cfg)
        return ({"params": params, "opt": opt}, {**metrics, **om},
                new_resid)

    return jax.jit(train_step, donate_argnums=(0, 2))


class FaabricTrainRuntime:
    """End-to-end training driver with control points."""

    def __init__(self, cfg: ArchConfig, opt_cfg: adamw.AdamWConfig,
                 data_cfg: dp.DataConfig, rt: RuntimeConfig,
                 devices: Optional[Sequence[Any]] = None,
                 job_id: str = "job0"):
        self.cfg, self.opt_cfg, self.data_cfg, self.rt = (cfg, opt_cfg,
                                                          data_cfg, rt)
        self.devices = list(devices if devices is not None
                            else jax.devices())
        self.job_id = job_id
        self.group: GranuleGroup = make_group_from_devices(
            job_id, self.devices, rt.chips_per_host, semantics="process")
        self.mesh = make_gang_mesh(self.devices, rt.pods)
        # Placement engine over the whole host fabric: the same code path
        # the simulator uses decides which chips this gang occupies at
        # rescale/migrate control points (paper §3.3/§3.4).
        self.fabric = list(jax.devices())
        cph = rt.chips_per_host
        n_hosts = -(-len(self.fabric) // cph)
        self.engine = PlacementEngine(n_hosts, cph,
                                      policy=rt.placement_policy)
        pad = n_hosts * cph - len(self.fabric)
        if pad:                       # phantom chips on the ragged last host
            self.engine.bind("_fabric-pad", [(n_hosts - 1, pad)])
        self.gang_alloc = self.engine.bind(
            job_id, self._placement_of(self.devices))
        self.ckpt = CheckpointManager(
            rt.ckpt_dir, job_id=job_id,
            incremental_every=rt.incremental_ckpt_every)
        self.control = ctl.ControlPointRunner(
            checkpoint_every=rt.checkpoint_every)
        self.log: List[Dict[str, Any]] = []
        self._step_fn = None
        self._extras = self._extra_specs()

    def _extra_specs(self):
        cfg = self.cfg
        b = self.data_cfg.global_batch
        if cfg.family == "audio":
            return {"frames": jax.ShapeDtypeStruct(
                (b, cfg.enc_seq, cfg.d_model), cfg.param_dtype())}
        if cfg.family == "vlm":
            return {"img": jax.ShapeDtypeStruct(
                (b, cfg.n_img_tokens, cfg.d_model), cfg.param_dtype())}
        return {}

    # ---- state/placement -----------------------------------------------------
    def _placement_of(self, devices: Sequence[Any]):
        """[(host, n_chips)] of a device list on the fabric's host grid."""
        idx = {d: i for i, d in enumerate(self.fabric)}
        counts: Dict[int, int] = {}
        for d in devices:
            h = idx[d] // self.rt.chips_per_host
            counts[h] = counts.get(h, 0) + 1
        return sorted(counts.items())

    def _devices_for(self, placement) -> List[Any]:
        """Concrete devices of an engine placement.  The engine models a
        single tenant (this gang + the fabric pad), so host h's first
        ``c`` chips are exactly the ones the placement owns."""
        cph = self.rt.chips_per_host
        out: List[Any] = []
        for h, c in placement:
            out.extend(self.fabric[h * cph:h * cph + c])
        return out

    def _shardings(self, state):
        rep = NamedSharding(self.mesh, P())
        return jax.tree.map(lambda _: rep, state)

    def _build(self):
        self._step_fn = make_dp_train_step(
            self.cfg, self.opt_cfg, self.mesh, self.rt.sync_mode,
            self.rt.compress_frac)

    def _place_batch(self, batch):
        axes = tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)
        s = NamedSharding(self.mesh, P(axes))
        return jax.tree.map(lambda x: jax.device_put(x, s), batch)

    def init_state(self, seed: int = 0):
        key = jax.random.PRNGKey(seed)
        with jax.default_device(self.devices[0]):
            state = model_mod.init_train_state(key, self.cfg, self.opt_cfg)
        return jax.device_put(state, self._shardings(state))

    # ---- control-point actions --------------------------------------------------
    def _recover(self, state, step):
        """Gang restart from the latest checkpoint (paper §3.4)."""
        restored, ck_step = self.ckpt.restore(
            shardings=self._shardings(state))
        return restored, ck_step

    def _migrate_gang(self, state):
        """Straggler response: live-migrate the gang (paper §3.3).

        The placement engine plans the move: a fragmented gang that now
        fits on fewer hosts is consolidated (the barrier-point
        defragmentation of Fig 8).  When no consolidation exists — e.g.
        the gang already spans the minimum host count — fall back to
        rotating the rank order within the same chips, which still
        exercises the full machinery: barrier point, live resharding,
        group re-addressing."""
        plans = self.engine.migration_plan([self.gang_alloc])
        if plans:
            _, new_pl = plans[0]
            self.gang_alloc = self.engine.apply_migration(
                self.gang_alloc, new_pl)
            new_devices = self._devices_for(new_pl)
        else:
            new_devices = self.devices[1:] + self.devices[:1]
        new_state, self.mesh = elastic_mod.reshard_gang(state, new_devices)
        if self.rt.pods > 1 and len(new_devices) % self.rt.pods == 0:
            self.mesh = make_gang_mesh(new_devices, self.rt.pods)
        self.devices = new_devices
        self.group = make_group_from_devices(
            self.job_id, new_devices, self.rt.chips_per_host)
        self._build()
        return new_state

    def _rescale(self, state, resid, new_world: int):
        """Grow/shrink the gang to ``new_world`` chips: release the gang's
        chips back to the shared pool and let the placement engine carve
        the new sub-mesh under the configured policy (paper §2.1)."""
        new_world = min(new_world, len(self.fabric))
        self.engine.release(self.gang_alloc)
        alloc = self.engine.allocate(self.job_id, new_world)
        assert alloc is not None, "rescale within fabric capacity"
        self.gang_alloc = alloc
        new_devices = self._devices_for(alloc.placement)
        state, self.mesh = elastic_mod.reshard_gang(state, new_devices)
        if self.rt.pods > 1 and len(new_devices) % self.rt.pods == 0:
            self.mesh = make_gang_mesh(new_devices, self.rt.pods)
        self.devices = new_devices
        self.group = make_group_from_devices(
            self.job_id, new_devices, self.rt.chips_per_host)
        self._build()
        resid = coll.init_residual_buffer(self.mesh, state["params"])
        return state, resid

    # ---- main loop ----------------------------------------------------------------
    def run(self, seed: int = 0, state=None):
        rt = self.rt
        self._build()
        if state is None:
            state = self.init_state(seed)
        resid = coll.init_residual_buffer(self.mesh, state["params"])
        # checkpoint step semantics: "state before running step k"
        self.ckpt.save(0, state, blocking=True)
        step = 0
        losses = {}
        recoveries = rescales = migrations = 0
        while step < rt.total_steps:
            # ---- control point A: failure detection before the step ----
            if step in rt.inject_failures and recoveries < 8:
                rt.inject_failures.pop(step, None)
                state, step = self._recover(state, step)
                recoveries += 1
                resid = coll.init_residual_buffer(self.mesh,
                                                  state["params"])
                continue
            t0 = time.time()
            batch = dp.make_batch(self.data_cfg, step, self._extras)
            batch = self._place_batch(batch)
            state, metrics, resid = self._step_fn(state, batch, resid)
            step_time = time.time() - t0
            loss = float(metrics["loss"])
            losses[step] = loss
            self.log.append({"step": step, "loss": loss,
                             "time": step_time,
                             "world": len(self.devices)})
            # ---- control point B (barrier: the grad sync is complete) ----
            actions = self.control.on_step(step + 1, step_time,
                                           len(self.devices))
            for act in actions:
                if act.kind == "checkpoint":
                    self.ckpt.save(step + 1, state, blocking=False)
                elif act.kind == "migrate":
                    state = self._migrate_gang(state)
                    migrations += 1
            if (step + 1) in rt.rescale_at:
                state, resid = self._rescale(state, resid,
                                             rt.rescale_at[step + 1])
                rescales += 1
            elif rt.elastic is not None:
                # free-chip-driven elasticity through the shared engine
                new_world = rt.elastic.decide(len(self.devices),
                                              self.engine)
                if new_world is not None:
                    state, resid = self._rescale(state, resid, new_world)
                    rescales += 1
            step += 1
        self.ckpt.wait()
        return state, {"losses": [losses[s] for s in sorted(losses)],
                       "recoveries": recoveries, "rescales": rescales,
                       "migrations": migrations, "log": self.log}
